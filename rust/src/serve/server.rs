//! The TCP front of the projection service (`l1inf serve`).
//!
//! A single **event-loop thread** owns every socket: it accepts
//! non-blocking connections, reads line-delimited JSON requests
//! ([`super::protocol`]) into per-connection buffers, and hands complete
//! lines to a **bounded worker pool** (`serve-worker-N`, one per
//! projection thread) draining a shared run queue. Workers parse, solve
//! and serialize; the event loop writes the rendered responses back.
//! Between bursts the loop parks in `poll(2)` over the listener, every
//! connection socket and a worker wake pipe, so an idle server consumes
//! no CPU — workers nudge the pipe (a classic self-pipe) after posting
//! each result, since an in-process channel send alone cannot make an
//! fd readable.
//! No thread is ever spawned per connection, so overload cannot spawn
//! unbounded threads — and every connection shares one
//! [`BatchProjector`] pool (matrix-sharded projections) and one
//! [`ThetaCache`] (cross-request, lock-free warm starts keyed by the
//! client-supplied matrix key). A `shutdown` op from any client drains
//! the in-flight requests and stops the loop — that is also how the
//! integration tests tear the server down. The full thread inventory and
//! ownership map lives in `docs/CONCURRENCY.md`.
//!
//! # Admission control
//!
//! At most `max_inflight` requests (config `serve.max_inflight` /
//! `--max-inflight`; 0 = unlimited) may be queued-or-running at once.
//! Past the cap the event loop **sheds**: it answers the line directly
//! with the typed `"overloaded"` error (see `docs/PROTOCOL.md`) without
//! ever touching the run queue, so overload degrades into fast typed
//! rejections instead of unbounded queueing. Every non-empty request
//! line increments exactly one of `serve.admission.accepted` or
//! `serve.admission.shed`. One request per connection is in flight at a
//! time; while it runs, the connection's socket is not read, so TCP
//! backpressure throttles pipelining clients for free.
//!
//! # Observability
//!
//! Every request records into the global metrics plane
//! ([`crate::util::metrics`]): per-op counters (`serve.op.*`), the
//! admission counters, a `serve.inflight` gauge, and the end-to-end
//! `serve.request.latency_us` histogram. `{"op":"stats"}` returns the
//! full snapshot; with `metrics_snapshot` configured the server also
//! rewrites a snapshot file on an interval and at shutdown (the vendored
//! crate set has no `libc`, so there is no SIGTERM hook — the interval +
//! shutdown writes cover orderly teardown, and `l1inf stats` reads the
//! file back offline).
//!
//! With tracing on (`[serve] trace = true` / `--trace`, or implied by a
//! `slow_ms` budget) every request line gets a trace id (echoed as
//! `"trace"` in its response) and records a span tree into the
//! [`crate::util::trace`] flight recorder: `serve.request` →
//! `serve.parse` / solver phases / `serve.respond` (all recorded on the
//! worker that runs the request). `{"op":"trace"}` drains the recorder
//! as JSON (`"clear":true` also resets it) and `l1inf trace` renders the
//! drain as Chrome trace-event JSON; requests over the `slow_ms` budget
//! log their phase breakdown at `warn` level.

use super::batch::{self, BatchProjector, ProjKind};
use super::cache::{CacheKey, DeltaStore, Family, ThetaCache};
use super::protocol::{self, DeltaRequest, ProjectRequest, Request};
use crate::config::serve::ServeConfig;
use crate::metric_counter;
use crate::projection::l1inf::{Algorithm, Delta};
use crate::util::json::Json;
use crate::util::Timer;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

/// Idle tick of the event loop on non-Unix targets, where the loop falls
/// back to a polled sleep when no socket or worker made progress. Short
/// enough that request latency stays sub-millisecond. On Unix the loop
/// parks in `poll(2)` instead (see `Waker`) and never spins.
#[cfg(not(unix))]
const IDLE_TICK: std::time::Duration = std::time::Duration::from_micros(300);

/// Heartbeat cap (ms) on one idle `poll(2)` wait. Readiness on any fd
/// ends the wait immediately; the cap only bounds how long a hypothetical
/// missed wakeup could be deferred (the wake pipe is level-triggered, so
/// no known path actually loses one).
#[cfg(unix)]
const IDLE_POLL_MS: i32 = 500;

/// `struct pollfd` from `poll(2)`. Declared locally: the vendored crate
/// set has no `libc`, but std always links the platform C library, so
/// the symbol is reachable through a plain `extern "C"` block.
#[cfg(unix)]
#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

#[cfg(unix)]
const POLLIN: i16 = 0x001;
#[cfg(unix)]
const POLLOUT: i16 = 0x004;

#[cfg(unix)]
extern "C" {
    fn poll(fds: *mut PollFd, nfds: std::ffi::c_ulong, timeout_ms: i32) -> i32;
}

/// Worker → event-loop wakeup. On Unix this is the write half of a
/// non-blocking socketpair: workers write one byte after posting a
/// `Done`, which makes the event loop's `poll(2)` set readable even
/// when every TCP socket is quiet. On other targets the loop sleeps
/// `IDLE_TICK` between checks and waking is a no-op.
#[derive(Clone)]
struct Waker {
    #[cfg(unix)]
    tx: Arc<std::os::unix::net::UnixStream>,
}

impl Waker {
    fn wake(&self) {
        #[cfg(unix)]
        {
            // WouldBlock means the pipe already holds unread wakeups, so
            // the event loop is guaranteed to wake and dropping this byte
            // is safe. Any other error only costs heartbeat latency.
            let _ = (&*self.tx).write(&[1u8]);
        }
    }
}

/// Drain every pending wakeup byte. Runs once per loop iteration *before*
/// the `Done` channel drain: a byte written after this drain belongs to a
/// `Done` that either lands in this iteration's `try_recv` or keeps the
/// pipe readable for the next `poll`, so a wakeup is never lost.
#[cfg(unix)]
fn drain_wakeups(mut wake_rx: &std::os::unix::net::UnixStream) {
    let mut buf = [0u8; 64];
    loop {
        match wake_rx.read(&mut buf) {
            Ok(0) => break, // every write half dropped (teardown)
            Ok(_) => continue,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break, // WouldBlock: pipe is empty
        }
    }
}

/// Park until a socket is ready, a worker posts a wakeup, or the
/// heartbeat expires. Level-triggered: anything that arrived before this
/// call keeps its fd readable, so `poll` returns immediately and the
/// loop re-derives readiness from scratch. `active` is false once a
/// shutdown is draining, when the loop no longer accepts or reads — only
/// worker completions and pending writes can then make progress.
#[cfg(unix)]
fn poll_wait(
    listener: &TcpListener,
    conns: &HashMap<u64, Conn>,
    wake_rx: &std::os::unix::net::UnixStream,
    active: bool,
) {
    use std::os::fd::AsRawFd;
    let mut fds = Vec::with_capacity(conns.len() + 2);
    fds.push(PollFd { fd: wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
    if active {
        fds.push(PollFd { fd: listener.as_raw_fd(), events: POLLIN, revents: 0 });
    }
    for conn in conns.values() {
        let mut events = 0i16;
        if active && !conn.in_flight && !conn.closed {
            events |= POLLIN;
        }
        if !conn.wbuf.is_empty() {
            events |= POLLOUT;
        }
        if events != 0 {
            fds.push(PollFd { fd: conn.stream.as_raw_fd(), events, revents: 0 });
        }
    }
    // SAFETY: `fds` is a live, exclusively borrowed `repr(C)` pollfd
    // array for the whole call, and `nfds` is its exact length.
    let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as std::ffi::c_ulong, IDLE_POLL_MS) };
    // 0 is the heartbeat, -1 is EINTR-class noise: both simply re-enter
    // the event loop, which rechecks every source anyway.
    let _ = rc;
}

/// Shared context: the event loop, every worker and the snapshot writer
/// hold a clone.
#[derive(Clone)]
struct Shared {
    pool: Arc<BatchProjector>,
    cache: Arc<ThetaCache>,
    /// Incremental-projection states for the `delta` op (keyed by the
    /// same typed namespaces as the θ cache; exact family only).
    deltas: Arc<DeltaStore>,
    served: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
    default_algo: Algorithm,
    /// Server start (the `uptime_secs` origin of stats responses).
    start: Instant,
    /// Snapshot file rewritten on an interval and at shutdown.
    metrics_snapshot: Option<Arc<str>>,
    metrics_interval_secs: f64,
    /// Log a phase breakdown of requests slower than this (ms; 0 = off).
    slow_ms: f64,
    /// Admission cap: queued-or-running requests; 0 = unlimited.
    max_inflight: usize,
}

impl Shared {
    /// The stats payload served over TCP and written to the snapshot file.
    fn stats_json(&self) -> std::collections::BTreeMap<String, Json> {
        protocol::stats_body(
            self.pool.threads(),
            self.served.load(Ordering::Relaxed),
            self.start.elapsed().as_secs_f64(),
            &self.cache.stats_by_family(),
            self.cache.stats(),
            crate::util::metrics::global().snapshot(),
        )
    }

    /// Write the snapshot file (no-op without `metrics_snapshot`).
    fn write_snapshot(&self) {
        if let Some(path) = self.metrics_snapshot.as_deref() {
            let doc = Json::Obj(self.stats_json()).to_string();
            if let Err(e) = std::fs::write(path, doc + "\n") {
                crate::warn!("serve: writing metrics snapshot {path}: {e}");
            }
        }
    }
}

/// One unit of work for the pool: a complete request line from one
/// connection, or the teardown sentinel.
enum WorkItem {
    Line { conn_id: u64, line: String },
    Exit,
}

/// The bounded run queue workers drain. Plain mutex + condvar: pushes
/// happen once per request on the event loop (not the θ hot path), and
/// workers block here between requests.
#[derive(Default)]
struct RunQueue {
    items: Mutex<VecDeque<WorkItem>>,
    ready: Condvar,
}

impl RunQueue {
    fn push(&self, item: WorkItem) {
        self.items.lock().expect("run queue poisoned").push_back(item);
        self.ready.notify_one();
    }

    fn pop(&self) -> WorkItem {
        let mut items = self.items.lock().expect("run queue poisoned");
        loop {
            if let Some(item) = items.pop_front() {
                return item;
            }
            items = self.ready.wait(items).expect("run queue poisoned");
        }
    }
}

/// A finished request: the rendered response line for `conn_id`.
struct Done {
    conn_id: u64,
    line: String,
    is_shutdown: bool,
}

/// Per-connection state owned by the event loop. All socket I/O is
/// non-blocking; partial reads/writes park in `rbuf`/`wbuf` until the
/// next readiness poll.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet consumed as complete lines.
    rbuf: Vec<u8>,
    /// Rendered response bytes not yet accepted by the socket.
    wbuf: Vec<u8>,
    /// A request from this connection is queued or running. While true
    /// the socket is not read (TCP backpressure) and no further line is
    /// dispatched, so responses keep request order.
    in_flight: bool,
    /// Read side saw EOF or an error; the connection is dropped once the
    /// write buffer drains and nothing is in flight.
    closed: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn { stream, rbuf: Vec::new(), wbuf: Vec::new(), in_flight: false, closed: false }
    }

    /// Drain the socket into `rbuf` until it would block. Returns true if
    /// any bytes arrived.
    fn fill(&mut self) -> bool {
        let mut progressed = false;
        let mut buf = [0u8; 4096];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.closed = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&buf[..n]);
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.closed = true;
                    break;
                }
            }
        }
        progressed
    }

    /// Next complete line from `rbuf` (newline stripped), or — once the
    /// read side closed — the unterminated tail, matching the old
    /// `BufRead::lines` behavior for clients that shut down their write
    /// half after a final newline-less request.
    fn next_line(&mut self) -> Option<String> {
        if let Some(pos) = self.rbuf.iter().position(|&b| b == b'\n') {
            let line = String::from_utf8_lossy(&self.rbuf[..pos]).into_owned();
            self.rbuf.drain(..=pos);
            return Some(line);
        }
        if self.closed && !self.rbuf.is_empty() {
            let line = String::from_utf8_lossy(&self.rbuf).into_owned();
            self.rbuf.clear();
            return Some(line);
        }
        None
    }

    /// Queue a response line for writing.
    fn push_response(&mut self, line: &str) {
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
    }

    /// Write as much of `wbuf` as the socket accepts without blocking.
    fn flush(&mut self) {
        while !self.wbuf.is_empty() {
            match self.stream.write(&self.wbuf) {
                Ok(0) => {
                    self.closed = true;
                    self.wbuf.clear();
                    break;
                }
                Ok(n) => {
                    self.wbuf.drain(..n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.closed = true;
                    self.wbuf.clear();
                    break;
                }
            }
        }
    }

    /// Teardown flush: switch back to blocking and push out whatever is
    /// left (e.g. the `shutdown` response), ignoring failures — the peer
    /// may already be gone.
    fn final_flush(&mut self) {
        if self.wbuf.is_empty() || self.closed {
            return;
        }
        let _ = self.stream.set_nonblocking(false);
        let _ = self.stream.write_all(&self.wbuf);
        let _ = self.stream.flush();
        self.wbuf.clear();
    }
}

/// A bound (but not yet running) projection service.
pub struct Server {
    listener: TcpListener,
    shared: Shared,
}

impl Server {
    /// Bind the listen socket and build the shared pool + cache.
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        // A slow-request budget needs the span trees to print, so it
        // implies recording.
        if cfg.trace || cfg.slow_ms > 0.0 {
            crate::util::trace::set_enabled(true);
        }
        let shared = Shared {
            pool: Arc::new(BatchProjector::new(cfg.threads)),
            cache: Arc::new(ThetaCache::new()),
            deltas: Arc::new(DeltaStore::new()),
            served: Arc::new(AtomicU64::new(0)),
            shutdown: Arc::new(AtomicBool::new(false)),
            default_algo: cfg.algo,
            start: Instant::now(),
            metrics_snapshot: cfg.metrics_snapshot.as_deref().map(Arc::from),
            metrics_interval_secs: cfg.metrics_interval_secs,
            slow_ms: cfg.slow_ms,
            max_inflight: cfg.max_inflight,
        };
        Ok(Server { listener, shared })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading bound address")
    }

    /// Worker threads in the projection pool (also the number of
    /// request-serving workers).
    pub fn threads(&self) -> usize {
        self.shared.pool.threads()
    }

    /// Run the readiness-polled event loop until a client sends
    /// `shutdown`; in-flight requests drain before it returns. The
    /// calling thread becomes the event loop; requests execute on the
    /// `serve-worker-N` pool.
    pub fn run(self) -> Result<()> {
        let Server { listener, shared } = self;
        let snapshot_writer = shared.metrics_snapshot.is_some().then(|| {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("serve-snapshot".to_string())
                .spawn(move || {
                    let interval =
                        std::time::Duration::from_secs_f64(shared.metrics_interval_secs.max(0.05));
                    // Poll the shutdown flag between short sleeps so teardown
                    // never waits a full interval.
                    let tick = interval.min(std::time::Duration::from_millis(200));
                    let mut next = Instant::now() + interval;
                    while !shared.shutdown.load(Ordering::SeqCst) {
                        std::thread::sleep(tick);
                        if Instant::now() >= next {
                            shared.write_snapshot();
                            next = Instant::now() + interval;
                        }
                    }
                })
                .expect("spawn snapshot writer")
        });

        listener.set_nonblocking(true).context("setting listener non-blocking")?;
        // Worker → event-loop wake pipe. Both halves non-blocking: a
        // worker never stalls on a full pipe and the drain never blocks.
        #[cfg(unix)]
        let (waker, wake_rx) = {
            let (wtx, wrx) =
                std::os::unix::net::UnixStream::pair().context("creating worker wake pipe")?;
            wtx.set_nonblocking(true).context("setting wake pipe non-blocking")?;
            wrx.set_nonblocking(true).context("setting wake pipe non-blocking")?;
            (Waker { tx: Arc::new(wtx) }, wrx)
        };
        #[cfg(not(unix))]
        let waker = Waker {};
        let queue = Arc::new(RunQueue::default());
        let (tx, rx) = mpsc::channel::<Done>();
        let workers: Vec<_> = (0..shared.pool.threads().max(1))
            .map(|w| {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                let shared = shared.clone();
                let waker = waker.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{w}"))
                    .spawn(move || worker_loop(&queue, &tx, &shared, &waker))
                    .expect("spawn serve worker")
            })
            .collect();
        drop(tx);

        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut conn_seq = 0u64;
        let mut inflight = 0usize;
        let mut stopping = false;
        loop {
            let mut progress = false;

            // ── accept ──────────────────────────────────────────────────
            if !stopping {
                loop {
                    match listener.accept() {
                        Ok((stream, peer)) => match stream.set_nonblocking(true) {
                            Ok(()) => {
                                conn_seq += 1;
                                conns.insert(conn_seq, Conn::new(stream));
                                progress = true;
                            }
                            Err(e) => crate::warn!("serve: non-blocking {peer}: {e}"),
                        },
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) => {
                            crate::warn!("serve: accept failed: {e}");
                            break;
                        }
                    }
                }
            }

            // ── read ready sockets, dispatch complete lines ─────────────
            if !stopping {
                for (&id, conn) in conns.iter_mut() {
                    if conn.in_flight || conn.closed {
                        continue;
                    }
                    progress |= conn.fill();
                    progress |= dispatch_ready(id, conn, &mut inflight, &shared, &queue);
                }
            }

            // ── collect finished requests ───────────────────────────────
            #[cfg(unix)]
            drain_wakeups(&wake_rx);
            while let Ok(done) = rx.try_recv() {
                progress = true;
                inflight -= 1;
                crate::metric_gauge!("serve.inflight").set(inflight as f64);
                if done.is_shutdown {
                    stopping = true;
                    shared.shutdown.store(true, Ordering::SeqCst);
                }
                if let Some(conn) = conns.get_mut(&done.conn_id) {
                    conn.push_response(&done.line);
                    conn.in_flight = false;
                    if !stopping {
                        // Pipelined lines already buffered dispatch now —
                        // the socket itself is only read again next tick.
                        dispatch_ready(done.conn_id, conn, &mut inflight, &shared, &queue);
                    }
                }
            }

            // ── write, then reap dead connections ───────────────────────
            conns.retain(|_, conn| {
                conn.flush();
                conn.in_flight || !conn.wbuf.is_empty() || !conn.closed
            });

            if stopping && inflight == 0 {
                break;
            }
            if !progress {
                // Nothing moved: park until a socket or worker is ready
                // instead of spinning on a sleep tick.
                #[cfg(unix)]
                poll_wait(&listener, &conns, &wake_rx, !stopping);
                #[cfg(not(unix))]
                std::thread::sleep(IDLE_TICK);
            }
        }

        // ── teardown: stop workers, push out buffered responses ─────────
        for _ in &workers {
            queue.push(WorkItem::Exit);
        }
        for handle in workers {
            let _ = handle.join();
        }
        for conn in conns.values_mut() {
            conn.final_flush();
        }
        if let Some(handle) = snapshot_writer {
            let _ = handle.join();
        }
        // Final write so post-mortem `l1inf stats` sees the full session.
        shared.write_snapshot();
        crate::info!("serve: shutdown requested, event loop stopped");
        Ok(())
    }
}

/// Pull complete lines out of `conn` and either enqueue them (admission
/// permitting) or shed them with the typed `"overloaded"` response. Stops
/// once a line is in flight — one request per connection at a time keeps
/// response order. Returns true if any line was consumed.
fn dispatch_ready(
    conn_id: u64,
    conn: &mut Conn,
    inflight: &mut usize,
    shared: &Shared,
    queue: &RunQueue,
) -> bool {
    let mut progressed = false;
    while !conn.in_flight {
        let Some(line) = conn.next_line() else { break };
        progressed = true;
        if line.trim().is_empty() {
            continue;
        }
        if shared.max_inflight > 0 && *inflight >= shared.max_inflight {
            // Shed on the event loop: never touches the run queue. The id
            // is recovered best-effort from the raw line so the client can
            // correlate the rejection.
            metric_counter!("serve.admission.shed").inc();
            conn.push_response(&protocol::overloaded_response(protocol::probe_id(&line)));
            continue;
        }
        metric_counter!("serve.admission.accepted").inc();
        *inflight += 1;
        crate::metric_gauge!("serve.inflight").set(*inflight as f64);
        conn.in_flight = true;
        queue.push(WorkItem::Line { conn_id, line });
    }
    progressed
}

/// One pool worker: block on the run queue, execute requests end to end
/// (parse → dispatch → serialize, all under the request's trace spans),
/// hand the rendered line back to the event loop.
fn worker_loop(queue: &RunQueue, results: &mpsc::Sender<Done>, shared: &Shared, waker: &Waker) {
    loop {
        let (conn_id, line) = match queue.pop() {
            WorkItem::Exit => return,
            WorkItem::Line { conn_id, line } => (conn_id, line),
        };
        // One trace id per request line; the root span scopes the whole
        // decode → solve → respond path so every solver phase lands as a
        // descendant in the span tree. Events publish when spans drop, so
        // the root closes (and the trace id is fully drainable) right
        // before the slow-budget check below.
        let t = Timer::start();
        let trace_id = crate::util::trace::enabled().then(crate::util::trace::next_trace_id);
        {
            let _root = trace_id.map(|tid| crate::util::trace::begin(tid, "serve.request"));
            let parsed = {
                let _p = crate::trace_span!("serve.parse");
                protocol::parse_request(&line, shared.default_algo)
            };
            let mut is_shutdown = false;
            let resp = match parsed {
                Err(e) => {
                    metric_counter!("serve.op.error").inc();
                    protocol::error_response(e.id, e.mode, &e.msg)
                }
                Ok(env) => match env.req {
                    Request::Ping => {
                        metric_counter!("serve.op.ping").inc();
                        protocol::pong_response(env.id)
                    }
                    Request::Stats => {
                        metric_counter!("serve.op.stats").inc();
                        protocol::stats_response(env.id, &shared.stats_json())
                    }
                    Request::Trace { clear } => {
                        metric_counter!("serve.op.trace").inc();
                        // Snapshot first, then clear: the drain never loses
                        // the events it is reporting.
                        let snap = crate::util::trace::snapshot();
                        if clear {
                            crate::util::trace::clear();
                        }
                        protocol::trace_response(env.id, &snap)
                    }
                    Request::Shutdown => {
                        metric_counter!("serve.op.shutdown").inc();
                        is_shutdown = true;
                        protocol::shutdown_response(env.id)
                    }
                    Request::Project(p) => {
                        metric_counter!("serve.op.project").inc();
                        run_project(env.id, *p, shared)
                    }
                    Request::Delta(d) => {
                        metric_counter!("serve.op.delta").inc();
                        run_delta(env.id, *d, shared)
                    }
                },
            };
            let resp = match trace_id {
                Some(tid) => protocol::with_trace_id(resp, tid),
                None => resp,
            };
            let _w = crate::trace_span!("serve.respond");
            if results.send(Done { conn_id, line: resp, is_shutdown }).is_err() {
                return; // event loop gone — teardown already past us
            }
            // The channel send alone cannot make an fd readable; the
            // pipe byte is what ends the event loop's idle poll.
            waker.wake();
        }
        if shared.slow_ms > 0.0 && t.millis() > shared.slow_ms {
            if let Some(tree) = trace_id.and_then(crate::util::trace::render_trace) {
                crate::warn!(
                    "serve: slow request {:.3}ms (budget {:.1}ms):\n{tree}",
                    t.millis(),
                    shared.slow_ms
                );
            }
        }
    }
}

fn run_project(id: i64, req: ProjectRequest, shared: &Shared) -> String {
    let _span = crate::util::metrics::span(
        "serve.request.latency_us",
        crate::metric_histogram!("serve.request.latency_us"),
    );
    let ProjectRequest {
        key,
        n_groups,
        group_len,
        radius,
        algo,
        mode,
        weights,
        depth,
        return_data,
        mut data,
    } = req;
    // θ*, τ and λ are different duals: warm starts live in per-family
    // typed keys of the shared cache (see [`batch::cache_key`]).
    let ns_key = key.as_deref().map(|k| batch::cache_key(mode, k));
    let hint = ns_key
        .as_ref()
        .and_then(|k| shared.cache.hint_for(k, n_groups, group_len));
    let response = match mode {
        ProjKind::Exact => {
            let t = Timer::start();
            let info = shared
                .pool
                .project_parallel(&mut data, n_groups, group_len, radius, algo, hint);
            let ms = t.millis();
            if let Some(k) = ns_key.as_ref() {
                if !info.feasible {
                    shared.cache.update(k, n_groups, group_len, info.theta);
                }
            }
            let payload = if return_data { Some(&data[..]) } else { None };
            protocol::project_response(id, &info, mode, hint.is_some(), ms, payload)
        }
        ProjKind::Bilevel => {
            let t = Timer::start();
            let info = shared
                .pool
                .project_bilevel_parallel(&mut data, n_groups, group_len, radius, hint);
            let ms = t.millis();
            if let Some(k) = ns_key.as_ref() {
                if !info.feasible {
                    shared.cache.update(k, n_groups, group_len, info.tau);
                }
            }
            let payload = if return_data { Some(&data[..]) } else { None };
            protocol::project_response(id, &info.to_proj_info(), mode, info.warm, ms, payload)
        }
        ProjKind::Weighted => {
            let t = Timer::start();
            let info = shared.pool.project_weighted(
                &mut data,
                n_groups,
                group_len,
                radius,
                weights.as_deref(),
                hint,
            );
            let ms = t.millis();
            if let Some(k) = ns_key.as_ref() {
                if !info.feasible {
                    shared.cache.update(k, n_groups, group_len, info.theta);
                }
            }
            let payload = if return_data { Some(&data[..]) } else { None };
            protocol::project_response(id, &info, mode, hint.is_some(), ms, payload)
        }
        ProjKind::Multilevel => {
            let t = Timer::start();
            let info = shared.pool.project_multilevel_parallel(
                &mut data,
                n_groups,
                group_len,
                radius,
                depth,
                hint,
            );
            let ms = t.millis();
            if let Some(k) = ns_key.as_ref() {
                if !info.feasible {
                    shared.cache.update(k, n_groups, group_len, info.tau);
                }
            }
            let payload = if return_data { Some(&data[..]) } else { None };
            protocol::project_response(id, &info.to_proj_info(), mode, info.warm, ms, payload)
        }
    };
    shared.served.fetch_add(1, Ordering::Relaxed);
    response
}

/// One `delta` op: init seeds a keyed [`crate::projection::l1inf::DeltaSolver`]
/// with a full cold solve; increments patch the changed rows into the
/// server-side matrix copy and repair only what moved. A key with no
/// persisted state (or a mismatched shape/radius) is a **typed error** —
/// never a silent cold solve — so clients always learn they must re-init.
/// Typed errors count under `serve.op.error` (like parse errors) and do
/// not bump `served`, so the stats surface reconciles uniformly.
fn run_delta(id: i64, req: DeltaRequest, shared: &Shared) -> String {
    let _span = crate::util::metrics::span(
        "serve.request.latency_us",
        crate::metric_histogram!("serve.request.latency_us"),
    );
    let DeltaRequest { key, n_groups, group_len, radius, init, rows, data, return_data } = req;
    let ck = CacheKey::new(Family::Exact, key.as_str());
    let mut ok = true;
    let response = if init {
        shared.deltas.init(&ck, data, radius, |e| {
            let t = Timer::start();
            match e.solver.begin(&e.y, n_groups, group_len) {
                Err(msg) => {
                    ok = false;
                    protocol::error_response(id, Some(ProjKind::Exact), &msg)
                }
                Ok(out) => {
                    if !out.info.feasible && out.info.theta > 0.0 {
                        shared.cache.update(&ck, n_groups, group_len, out.info.theta);
                    }
                    let payload = return_data.then(|| e.solver.x());
                    protocol::delta_response(
                        id,
                        &out.info,
                        out.repaired_groups,
                        out.fallback,
                        false,
                        t.millis(),
                        payload,
                    )
                }
            }
        })
    } else {
        let served = shared.deltas.with_entry(&ck, |e| {
            let (pn, pm) = e.solver.shape();
            if (pn, pm) != (n_groups, group_len) {
                ok = false;
                return protocol::error_response(
                    id,
                    Some(ProjKind::Exact),
                    &format!(
                        "delta: persisted state under '{ck}' has shape {pn}x{pm}, \
                         request says {n_groups}x{group_len}; re-send with \"init\":true"
                    ),
                );
            }
            if e.solver.c() != radius {
                ok = false;
                return protocol::error_response(
                    id,
                    Some(ProjKind::Exact),
                    &format!(
                        "delta: persisted state under '{ck}' tracks radius {}, \
                         request says {radius}; re-send with \"init\":true",
                        e.solver.c()
                    ),
                );
            }
            let t = Timer::start();
            for (i, &g) in rows.iter().enumerate() {
                let g = g as usize;
                e.y[g * group_len..(g + 1) * group_len]
                    .copy_from_slice(&data[i * group_len..(i + 1) * group_len]);
            }
            let delta = Delta::from_rows(rows.iter().copied());
            match e.solver.solve_delta(&e.y, &delta) {
                Err(msg) => {
                    ok = false;
                    protocol::error_response(id, Some(ProjKind::Exact), &msg)
                }
                Ok(out) => {
                    if !out.info.feasible && out.info.theta > 0.0 {
                        shared.cache.update(&ck, n_groups, group_len, out.info.theta);
                    }
                    let payload = return_data.then(|| e.solver.x());
                    protocol::delta_response(
                        id,
                        &out.info,
                        out.repaired_groups,
                        out.fallback,
                        true,
                        t.millis(),
                        payload,
                    )
                }
            }
        });
        served.unwrap_or_else(|| {
            ok = false;
            protocol::error_response(
                id,
                Some(ProjKind::Exact),
                &format!(
                    "delta: no persisted state under key '{ck}' \
                     (exact family namespace); send \"init\":true first"
                ),
            )
        })
    };
    if ok {
        shared.served.fetch_add(1, Ordering::Relaxed);
    } else {
        metric_counter!("serve.op.error").inc();
    }
    response
}
