//! The TCP front of the projection service (`l1inf serve`).
//!
//! One OS thread per connection decodes line-delimited JSON requests
//! ([`super::protocol`]); every connection shares one
//! [`BatchProjector`] pool (matrix-sharded projections) and one
//! [`ThetaCache`] (cross-request warm starts keyed by the client-supplied
//! matrix key). A `shutdown` op from any client stops the accept loop —
//! that is also how the integration tests tear the server down.
//!
//! # Observability
//!
//! Every request records into the global metrics plane
//! ([`crate::util::metrics`]): per-op counters (`serve.op.*`), an
//! in-flight gauge, and the end-to-end `serve.request.latency_us`
//! histogram. `{"op":"stats"}` returns the full snapshot; with
//! `metrics_snapshot` configured the server also rewrites a snapshot file
//! on an interval and at shutdown (the vendored crate set has no `libc`,
//! so there is no SIGTERM hook — the interval + shutdown writes cover
//! orderly teardown, and `l1inf stats` reads the file back offline).
//!
//! With tracing on (`[serve] trace = true` / `--trace`, or implied by a
//! `slow_ms` budget) every request line gets a trace id (echoed as
//! `"trace"` in its response) and records a span tree into the
//! [`crate::util::trace`] flight recorder: `serve.request` →
//! `serve.parse` / solver phases / `serve.respond`. `{"op":"trace"}`
//! drains the recorder as JSON (`"clear":true` also resets it) and
//! `l1inf trace` renders the drain as Chrome trace-event JSON; requests
//! over the `slow_ms` budget log their phase breakdown at `warn` level.

use super::batch::{self, BatchProjector, ProjKind};
use super::cache::{CacheKey, DeltaStore, Family, ThetaCache};
use super::protocol::{self, DeltaRequest, ProjectRequest, Request};
use crate::projection::l1inf::Delta;
use crate::config::serve::ServeConfig;
use crate::metric_counter;
use crate::projection::l1inf::Algorithm;
use crate::util::json::Json;
use crate::util::Timer;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Shared per-connection context.
#[derive(Clone)]
struct Shared {
    pool: Arc<BatchProjector>,
    cache: Arc<ThetaCache>,
    /// Incremental-projection states for the `delta` op (keyed by the
    /// same typed namespaces as the θ cache; exact family only).
    deltas: Arc<DeltaStore>,
    served: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
    default_algo: Algorithm,
    addr: SocketAddr,
    /// Server start (the `uptime_secs` origin of stats responses).
    start: Instant,
    /// Snapshot file rewritten on an interval and at shutdown.
    metrics_snapshot: Option<Arc<str>>,
    metrics_interval_secs: f64,
    /// Log a phase breakdown of requests slower than this (ms; 0 = off).
    slow_ms: f64,
}

impl Shared {
    /// The stats payload served over TCP and written to the snapshot file.
    fn stats_json(&self) -> std::collections::BTreeMap<String, Json> {
        protocol::stats_body(
            self.pool.threads(),
            self.served.load(Ordering::Relaxed),
            self.start.elapsed().as_secs_f64(),
            &self.cache.stats_by_family(),
            self.cache.stats(),
            crate::util::metrics::global().snapshot(),
        )
    }

    /// Write the snapshot file (no-op without `metrics_snapshot`).
    fn write_snapshot(&self) {
        if let Some(path) = self.metrics_snapshot.as_deref() {
            let doc = Json::Obj(self.stats_json()).to_string();
            if let Err(e) = std::fs::write(path, doc + "\n") {
                crate::warn!("serve: writing metrics snapshot {path}: {e}");
            }
        }
    }
}

/// A bound (but not yet running) projection service.
pub struct Server {
    listener: TcpListener,
    shared: Shared,
}

impl Server {
    /// Bind the listen socket and build the shared pool + cache.
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("reading bound address")?;
        // A slow-request budget needs the span trees to print, so it
        // implies recording.
        if cfg.trace || cfg.slow_ms > 0.0 {
            crate::util::trace::set_enabled(true);
        }
        let shared = Shared {
            pool: Arc::new(BatchProjector::new(cfg.threads)),
            cache: Arc::new(ThetaCache::new()),
            deltas: Arc::new(DeltaStore::new()),
            served: Arc::new(AtomicU64::new(0)),
            shutdown: Arc::new(AtomicBool::new(false)),
            default_algo: cfg.algo,
            addr,
            start: Instant::now(),
            metrics_snapshot: cfg.metrics_snapshot.as_deref().map(Arc::from),
            metrics_interval_secs: cfg.metrics_interval_secs,
            slow_ms: cfg.slow_ms,
        };
        Ok(Server { listener, shared })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading bound address")
    }

    /// Worker threads in the projection pool.
    pub fn threads(&self) -> usize {
        self.shared.pool.threads()
    }

    /// Accept-and-serve until a client sends `shutdown`. Each connection
    /// gets its own decoding thread; projections run on the shared pool.
    pub fn run(self) -> Result<()> {
        let snapshot_writer = self.shared.metrics_snapshot.is_some().then(|| {
            let shared = self.shared.clone();
            std::thread::Builder::new()
                .name("serve-snapshot".to_string())
                .spawn(move || {
                    let interval =
                        std::time::Duration::from_secs_f64(shared.metrics_interval_secs.max(0.05));
                    // Poll the shutdown flag between short sleeps so teardown
                    // never waits a full interval.
                    let tick = interval.min(std::time::Duration::from_millis(200));
                    let mut next = Instant::now() + interval;
                    while !shared.shutdown.load(Ordering::SeqCst) {
                        std::thread::sleep(tick);
                        if Instant::now() >= next {
                            shared.write_snapshot();
                            next = Instant::now() + interval;
                        }
                    }
                })
                .expect("spawn snapshot writer")
        });
        let mut conn_seq = 0u64;
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(stream) => {
                    let shared = self.shared.clone();
                    conn_seq += 1;
                    std::thread::Builder::new()
                        .name(format!("serve-conn-{conn_seq}"))
                        .spawn(move || {
                            let peer = stream
                                .peer_addr()
                                .map(|a| a.to_string())
                                .unwrap_or_else(|_| "?".into());
                            if let Err(e) = handle_connection(stream, &shared) {
                                crate::debug!("serve: connection {peer} closed: {e}");
                            }
                        })
                        .expect("spawn connection handler");
                }
                Err(e) => crate::warn!("serve: accept failed: {e}"),
            }
        }
        if let Some(handle) = snapshot_writer {
            let _ = handle.join();
        }
        // Final write so post-mortem `l1inf stats` sees the full session.
        self.shared.write_snapshot();
        crate::info!("serve: shutdown requested, accept loop stopped");
        Ok(())
    }
}

/// Address the shutdown handler connects to in order to wake the accept
/// loop. A wildcard bind (0.0.0.0 / ::) is not connectable on every
/// platform — substitute the matching loopback.
fn wake_addr(mut addr: SocketAddr) -> SocketAddr {
    if addr.ip().is_unspecified() {
        match addr {
            SocketAddr::V4(_) => addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)),
            SocketAddr::V6(_) => addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)),
        }
    }
    addr
}

fn write_line(writer: &mut BufWriter<TcpStream>, line: &str) -> std::io::Result<()> {
    writer.write_all(line.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

fn handle_connection(stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // One trace id per request line; the root span scopes the whole
        // decode → solve → respond path so every solver phase lands as a
        // descendant in the span tree. Events publish when spans drop, so
        // the root closes (and the trace id is fully drainable) right
        // before the slow-budget check below.
        let t = Timer::start();
        let trace_id =
            crate::util::trace::enabled().then(crate::util::trace::next_trace_id);
        let mut is_shutdown = false;
        {
            let _root = trace_id.map(|tid| crate::util::trace::begin(tid, "serve.request"));
            let parsed = {
                let _p = crate::trace_span!("serve.parse");
                protocol::parse_request(&line, shared.default_algo)
            };
            let resp = match parsed {
                Err(e) => {
                    metric_counter!("serve.op.error").inc();
                    protocol::error_response(e.id, e.mode, &e.msg)
                }
                Ok(env) => match env.req {
                    Request::Ping => {
                        metric_counter!("serve.op.ping").inc();
                        protocol::pong_response(env.id)
                    }
                    Request::Stats => {
                        metric_counter!("serve.op.stats").inc();
                        protocol::stats_response(env.id, &shared.stats_json())
                    }
                    Request::Trace { clear } => {
                        metric_counter!("serve.op.trace").inc();
                        // Snapshot first, then clear: the drain never loses
                        // the events it is reporting.
                        let snap = crate::util::trace::snapshot();
                        if clear {
                            crate::util::trace::clear();
                        }
                        protocol::trace_response(env.id, &snap)
                    }
                    Request::Shutdown => {
                        metric_counter!("serve.op.shutdown").inc();
                        is_shutdown = true;
                        protocol::shutdown_response(env.id)
                    }
                    Request::Project(p) => {
                        metric_counter!("serve.op.project").inc();
                        run_project(env.id, *p, shared)
                    }
                    Request::Delta(d) => {
                        metric_counter!("serve.op.delta").inc();
                        run_delta(env.id, *d, shared)
                    }
                },
            };
            let resp = match trace_id {
                Some(tid) => protocol::with_trace_id(resp, tid),
                None => resp,
            };
            let _w = crate::trace_span!("serve.respond");
            write_line(&mut writer, &resp)?;
        }
        if shared.slow_ms > 0.0 && t.millis() > shared.slow_ms {
            if let Some(tree) = trace_id.and_then(crate::util::trace::render_trace) {
                crate::warn!(
                    "serve: slow request {:.3}ms (budget {:.1}ms):\n{tree}",
                    t.millis(),
                    shared.slow_ms
                );
            }
        }
        if is_shutdown {
            shared.shutdown.store(true, Ordering::SeqCst);
            // Unblock the (blocking) accept loop with a no-op connection
            // so it observes the flag and exits.
            let _ = TcpStream::connect(wake_addr(shared.addr));
            return Ok(());
        }
    }
    Ok(())
}

fn run_project(id: i64, req: ProjectRequest, shared: &Shared) -> String {
    let _span = crate::util::metrics::span(
        "serve.request.latency_us",
        crate::metric_histogram!("serve.request.latency_us"),
    );
    let ProjectRequest {
        key,
        n_groups,
        group_len,
        radius,
        algo,
        mode,
        weights,
        return_data,
        mut data,
    } = req;
    // θ*, τ and λ are different duals: warm starts live in per-family
    // typed keys of the shared cache (see [`batch::cache_key`]).
    let ns_key = key.as_deref().map(|k| batch::cache_key(mode, k));
    let hint = ns_key
        .as_ref()
        .and_then(|k| shared.cache.hint_for(k, n_groups, group_len));
    let response = match mode {
        ProjKind::Exact => {
            let t = Timer::start();
            let info = shared
                .pool
                .project_parallel(&mut data, n_groups, group_len, radius, algo, hint);
            let ms = t.millis();
            if let Some(k) = ns_key.as_ref() {
                if !info.feasible {
                    shared.cache.update(k, n_groups, group_len, radius, info.theta);
                }
            }
            let payload = if return_data { Some(&data[..]) } else { None };
            protocol::project_response(id, &info, mode, hint.is_some(), ms, payload)
        }
        ProjKind::Bilevel => {
            let t = Timer::start();
            let info = shared
                .pool
                .project_bilevel_parallel(&mut data, n_groups, group_len, radius, hint);
            let ms = t.millis();
            if let Some(k) = ns_key.as_ref() {
                if !info.feasible {
                    shared.cache.update(k, n_groups, group_len, radius, info.tau);
                }
            }
            let payload = if return_data { Some(&data[..]) } else { None };
            protocol::project_response(id, &info.to_proj_info(), mode, info.warm, ms, payload)
        }
        ProjKind::Weighted => {
            let t = Timer::start();
            let info = shared.pool.project_weighted(
                &mut data,
                n_groups,
                group_len,
                radius,
                weights.as_deref(),
                hint,
            );
            let ms = t.millis();
            if let Some(k) = ns_key.as_ref() {
                if !info.feasible {
                    shared.cache.update(k, n_groups, group_len, radius, info.theta);
                }
            }
            let payload = if return_data { Some(&data[..]) } else { None };
            protocol::project_response(id, &info, mode, hint.is_some(), ms, payload)
        }
    };
    shared.served.fetch_add(1, Ordering::Relaxed);
    response
}

/// One `delta` op: init seeds a keyed [`crate::projection::l1inf::DeltaSolver`]
/// with a full cold solve; increments patch the changed rows into the
/// server-side matrix copy and repair only what moved. A key with no
/// persisted state (or a mismatched shape/radius) is a **typed error** —
/// never a silent cold solve — so clients always learn they must re-init.
/// Typed errors count under `serve.op.error` (like parse errors) and do
/// not bump `served`, so the stats surface reconciles uniformly.
fn run_delta(id: i64, req: DeltaRequest, shared: &Shared) -> String {
    let _span = crate::util::metrics::span(
        "serve.request.latency_us",
        crate::metric_histogram!("serve.request.latency_us"),
    );
    let DeltaRequest { key, n_groups, group_len, radius, init, rows, data, return_data } = req;
    let ck = CacheKey::new(Family::Exact, key.as_str());
    let mut ok = true;
    let response = if init {
        shared.deltas.init(&ck, data, radius, |e| {
            let t = Timer::start();
            match e.solver.begin(&e.y, n_groups, group_len) {
                Err(msg) => {
                    ok = false;
                    protocol::error_response(id, Some(ProjKind::Exact), &msg)
                }
                Ok(out) => {
                    if !out.info.feasible && out.info.theta > 0.0 {
                        shared.cache.update(&ck, n_groups, group_len, radius, out.info.theta);
                    }
                    let payload = return_data.then(|| e.solver.x());
                    protocol::delta_response(
                        id,
                        &out.info,
                        out.repaired_groups,
                        out.fallback,
                        false,
                        t.millis(),
                        payload,
                    )
                }
            }
        })
    } else {
        let served = shared.deltas.with_entry(&ck, |e| {
            let (pn, pm) = e.solver.shape();
            if (pn, pm) != (n_groups, group_len) {
                ok = false;
                return protocol::error_response(
                    id,
                    Some(ProjKind::Exact),
                    &format!(
                        "delta: persisted state under '{ck}' has shape {pn}x{pm}, \
                         request says {n_groups}x{group_len}; re-send with \"init\":true"
                    ),
                );
            }
            if e.solver.c() != radius {
                ok = false;
                return protocol::error_response(
                    id,
                    Some(ProjKind::Exact),
                    &format!(
                        "delta: persisted state under '{ck}' tracks radius {}, \
                         request says {radius}; re-send with \"init\":true",
                        e.solver.c()
                    ),
                );
            }
            let t = Timer::start();
            for (i, &g) in rows.iter().enumerate() {
                let g = g as usize;
                e.y[g * group_len..(g + 1) * group_len]
                    .copy_from_slice(&data[i * group_len..(i + 1) * group_len]);
            }
            let delta = Delta::from_rows(rows.iter().copied());
            match e.solver.solve_delta(&e.y, &delta) {
                Err(msg) => {
                    ok = false;
                    protocol::error_response(id, Some(ProjKind::Exact), &msg)
                }
                Ok(out) => {
                    if !out.info.feasible && out.info.theta > 0.0 {
                        shared.cache.update(&ck, n_groups, group_len, radius, out.info.theta);
                    }
                    let payload = return_data.then(|| e.solver.x());
                    protocol::delta_response(
                        id,
                        &out.info,
                        out.repaired_groups,
                        out.fallback,
                        true,
                        t.millis(),
                        payload,
                    )
                }
            }
        });
        served.unwrap_or_else(|| {
            ok = false;
            protocol::error_response(
                id,
                Some(ProjKind::Exact),
                &format!(
                    "delta: no persisted state under key '{ck}' \
                     (exact family namespace); send \"init\":true first"
                ),
            )
        })
    };
    if ok {
        shared.served.fetch_add(1, Ordering::Relaxed);
    } else {
        metric_counter!("serve.op.error").inc();
    }
    response
}
