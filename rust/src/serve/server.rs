//! The TCP front of the projection service (`l1inf serve`).
//!
//! One OS thread per connection decodes line-delimited JSON requests
//! ([`super::protocol`]); every connection shares one
//! [`BatchProjector`] pool (matrix-sharded projections) and one
//! [`ThetaCache`] (cross-request warm starts keyed by the client-supplied
//! matrix key). A `shutdown` op from any client stops the accept loop —
//! that is also how the integration tests tear the server down.

use super::batch::{self, BatchProjector, ProjKind};
use super::cache::ThetaCache;
use super::protocol::{self, ProjectRequest, Request};
use crate::config::serve::ServeConfig;
use crate::projection::l1inf::Algorithm;
use crate::util::Timer;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Shared per-connection context.
#[derive(Clone)]
struct Shared {
    pool: Arc<BatchProjector>,
    cache: Arc<ThetaCache>,
    served: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
    default_algo: Algorithm,
    addr: SocketAddr,
}

/// A bound (but not yet running) projection service.
pub struct Server {
    listener: TcpListener,
    shared: Shared,
}

impl Server {
    /// Bind the listen socket and build the shared pool + cache.
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("reading bound address")?;
        let shared = Shared {
            pool: Arc::new(BatchProjector::new(cfg.threads)),
            cache: Arc::new(ThetaCache::new()),
            served: Arc::new(AtomicU64::new(0)),
            shutdown: Arc::new(AtomicBool::new(false)),
            default_algo: cfg.algo,
            addr,
        };
        Ok(Server { listener, shared })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading bound address")
    }

    /// Worker threads in the projection pool.
    pub fn threads(&self) -> usize {
        self.shared.pool.threads()
    }

    /// Accept-and-serve until a client sends `shutdown`. Each connection
    /// gets its own decoding thread; projections run on the shared pool.
    pub fn run(self) -> Result<()> {
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(stream) => {
                    let shared = self.shared.clone();
                    std::thread::spawn(move || {
                        let peer = stream
                            .peer_addr()
                            .map(|a| a.to_string())
                            .unwrap_or_else(|_| "?".into());
                        if let Err(e) = handle_connection(stream, &shared) {
                            crate::debug!("serve: connection {peer} closed: {e}");
                        }
                    });
                }
                Err(e) => crate::warn!("serve: accept failed: {e}"),
            }
        }
        crate::info!("serve: shutdown requested, accept loop stopped");
        Ok(())
    }
}

/// Address the shutdown handler connects to in order to wake the accept
/// loop. A wildcard bind (0.0.0.0 / ::) is not connectable on every
/// platform — substitute the matching loopback.
fn wake_addr(mut addr: SocketAddr) -> SocketAddr {
    if addr.ip().is_unspecified() {
        match addr {
            SocketAddr::V4(_) => addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)),
            SocketAddr::V6(_) => addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)),
        }
    }
    addr
}

fn write_line(writer: &mut BufWriter<TcpStream>, line: &str) -> std::io::Result<()> {
    writer.write_all(line.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

fn handle_connection(stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_request(&line, shared.default_algo) {
            Err((id, msg)) => write_line(&mut writer, &protocol::error_response(id, &msg))?,
            Ok(env) => match env.req {
                Request::Ping => write_line(&mut writer, &protocol::pong_response(env.id))?,
                Request::Stats => {
                    let resp = protocol::stats_response(
                        env.id,
                        shared.pool.threads(),
                        shared.served.load(Ordering::Relaxed),
                        shared.cache.stats(),
                    );
                    write_line(&mut writer, &resp)?;
                }
                Request::Shutdown => {
                    write_line(&mut writer, &protocol::shutdown_response(env.id))?;
                    shared.shutdown.store(true, Ordering::SeqCst);
                    // Unblock the (blocking) accept loop with a no-op
                    // connection so it observes the flag and exits.
                    let _ = TcpStream::connect(wake_addr(shared.addr));
                    return Ok(());
                }
                Request::Project(p) => {
                    let resp = run_project(env.id, *p, shared);
                    write_line(&mut writer, &resp)?;
                }
            },
        }
    }
    Ok(())
}

fn run_project(id: i64, req: ProjectRequest, shared: &Shared) -> String {
    let ProjectRequest {
        key,
        n_groups,
        group_len,
        radius,
        algo,
        mode,
        weights,
        return_data,
        mut data,
    } = req;
    // θ*, τ and λ are different duals: warm starts live in per-family
    // typed keys of the shared cache (see [`batch::cache_key`]).
    let ns_key = key.as_deref().map(|k| batch::cache_key(mode, k));
    let hint = ns_key
        .as_ref()
        .and_then(|k| shared.cache.hint_for(k, n_groups, group_len));
    let response = match mode {
        ProjKind::Exact => {
            let t = Timer::start();
            let info = shared
                .pool
                .project_parallel(&mut data, n_groups, group_len, radius, algo, hint);
            let ms = t.millis();
            if let Some(k) = ns_key.as_ref() {
                if !info.feasible {
                    shared.cache.update(k, n_groups, group_len, radius, info.theta);
                }
            }
            let payload = if return_data { Some(&data[..]) } else { None };
            protocol::project_response(id, &info, mode, hint.is_some(), ms, payload)
        }
        ProjKind::Bilevel => {
            let t = Timer::start();
            let info = shared
                .pool
                .project_bilevel_parallel(&mut data, n_groups, group_len, radius, hint);
            let ms = t.millis();
            if let Some(k) = ns_key.as_ref() {
                if !info.feasible {
                    shared.cache.update(k, n_groups, group_len, radius, info.tau);
                }
            }
            let payload = if return_data { Some(&data[..]) } else { None };
            protocol::project_response(id, &info.to_proj_info(), mode, info.warm, ms, payload)
        }
        ProjKind::Weighted => {
            let t = Timer::start();
            let info = shared.pool.project_weighted(
                &mut data,
                n_groups,
                group_len,
                radius,
                weights.as_deref(),
                hint,
            );
            let ms = t.millis();
            if let Some(k) = ns_key.as_ref() {
                if !info.feasible {
                    shared.cache.update(k, n_groups, group_len, radius, info.theta);
                }
            }
            let payload = if return_data { Some(&data[..]) } else { None };
            protocol::project_response(id, &info, mode, hint.is_some(), ms, payload)
        }
    };
    shared.served.fetch_add(1, Ordering::Relaxed);
    response
}
