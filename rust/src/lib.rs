//! # l1inf — Near-Linear Time Projection onto the ℓ₁,∞ Ball
//!
//! Production reproduction of Perez, Condat & Barlaud (2023),
//! *"Near-Linear Time Projection onto the ℓ₁,∞ Ball; Application to Sparse
//! Autoencoders"*, as a three-layer rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the paper's algorithmic contribution
//!   ([`projection::l1inf::inverse_order`]) plus every baseline it compares
//!   against, the supervised-autoencoder training coordinator ([`sae`]), the
//!   data substrates ([`data`]), and the PJRT runtime ([`runtime`]) that
//!   executes AOT-compiled JAX/Pallas artifacts.
//! - **Layer 2** — `python/compile/model.py`: the SAE forward/backward +
//!   Adam as a JAX function, lowered once to HLO text (`make artifacts`).
//! - **Layer 1** — `python/compile/kernels/`: Pallas kernels (tiled dense
//!   layers with a custom VJP, column-clip) called from the L2 graph.
//!
//! Python never runs at training/serving time: the rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and drives
//! everything else natively.
//!
//! ## Quick start
//!
//! (`no_run`: rustdoc test binaries don't inherit the cargo rpath to
//! `libxla_extension`; the same API is exercised by the unit tests.)
//!
//! ```no_run
//! use l1inf::projection::l1inf::{project_l1inf, Algorithm};
//!
//! // 3 groups ("columns" in the paper) of length 4, ‖Y‖₁,∞ = 3.0
//! let mut y = vec![
//!     1.0f32, -0.5, 0.25, 0.0, // group 0, max |.| = 1.0
//!     0.9, 0.8, -0.7, 0.1,     // group 1, max |.| = 0.9
//!     1.1, 0.2, 0.3, -0.4,     // group 2, max |.| = 1.1
//! ];
//! let info = project_l1inf(&mut y, 3, 4, 1.5, Algorithm::InverseOrder);
//! assert!(info.radius_after <= 1.5 + 1e-5);
//! ```
//!
//! See `examples/` for end-to-end drivers and `rust/src/experiments/` for
//! the code that regenerates every table and figure of the paper.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod projection;
pub mod runtime;
pub mod sae;
pub mod serve;
pub mod util;

/// Crate-level result alias.
pub type Result<T> = anyhow::Result<T>;
