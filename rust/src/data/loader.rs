//! Preprocessing + batching: stratified splits, the paper's log-transform,
//! z-score standardization, padded eval batches and epoch permutations.

use super::Dataset;
use crate::runtime::Tensor;
use crate::util::rng::Rng;

/// In-place log-transform `x ← ln(1 + x)` — the paper applies a
/// log-transform to the metabolomic data "for reducing heteroscedasticity
/// and transforming multiplicative noise into additive noise".
pub fn log_transform(ds: &mut Dataset) {
    for v in ds.x.iter_mut() {
        debug_assert!(*v >= 0.0, "log-transform expects nonnegative intensities");
        *v = (1.0 + *v).ln();
    }
}

/// Per-feature standardization statistics (computed on the train split).
#[derive(Debug, Clone)]
pub struct Standardizer {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl Standardizer {
    /// Fit on the rows of `ds` listed in `idx`.
    pub fn fit(ds: &Dataset, idx: &[usize]) -> Standardizer {
        let d = ds.d;
        let mut mean = vec![0.0f64; d];
        let mut sq = vec![0.0f64; d];
        for &i in idx {
            let row = ds.row(i);
            for j in 0..d {
                mean[j] += row[j] as f64;
                sq[j] += (row[j] as f64) * (row[j] as f64);
            }
        }
        let n = idx.len().max(1) as f64;
        let mut m32 = vec![0.0f32; d];
        let mut s32 = vec![0.0f32; d];
        for j in 0..d {
            let mu = mean[j] / n;
            let var = (sq[j] / n - mu * mu).max(1e-12);
            m32[j] = mu as f32;
            s32[j] = var.sqrt() as f32;
        }
        Standardizer { mean: m32, std: s32 }
    }

    /// Apply to a raw row, writing into `out`.
    pub fn apply(&self, row: &[f32], out: &mut [f32]) {
        for j in 0..row.len() {
            out[j] = (row[j] - self.mean[j]) / self.std[j];
        }
    }
}

/// A ready-to-train split: standardized train/test tensors.
#[derive(Debug, Clone)]
pub struct Split {
    pub x_train: Vec<f32>,
    pub y_train: Vec<i32>,
    pub x_test: Vec<f32>,
    pub y_test: Vec<i32>,
    pub n_train: usize,
    pub n_test: usize,
    pub d: usize,
    pub k: usize,
}

/// Stratified split + standardization fitted on train only.
/// `n_train_target` rows go to train (truncated to a multiple of nothing —
/// the trainer later slices `cfg.n_train` rows as the epoch window).
pub fn stratified_split(ds: &Dataset, train_frac: f64, seed: u64) -> Split {
    let mut rng = Rng::new(seed ^ 0x5711F7);
    // bucket indices per class, shuffle, take train_frac of each
    let mut train_idx = Vec::new();
    let mut test_idx = Vec::new();
    for c in 0..ds.k {
        let mut idx: Vec<usize> = (0..ds.n).filter(|&i| ds.y[i] as usize == c).collect();
        rng.shuffle(&mut idx);
        let n_tr = ((idx.len() as f64) * train_frac).round() as usize;
        train_idx.extend_from_slice(&idx[..n_tr]);
        test_idx.extend_from_slice(&idx[n_tr..]);
    }
    rng.shuffle(&mut train_idx);
    rng.shuffle(&mut test_idx);

    let st = Standardizer::fit(ds, &train_idx);
    let pack = |idx: &[usize]| {
        let mut x = vec![0.0f32; idx.len() * ds.d];
        let mut y = vec![0i32; idx.len()];
        for (r, &i) in idx.iter().enumerate() {
            st.apply(ds.row(i), &mut x[r * ds.d..(r + 1) * ds.d]);
            y[r] = ds.y[i];
        }
        (x, y)
    };
    let (x_train, y_train) = pack(&train_idx);
    let (x_test, y_test) = pack(&test_idx);
    Split {
        n_train: train_idx.len(),
        n_test: test_idx.len(),
        d: ds.d,
        k: ds.k,
        x_train,
        y_train,
        x_test,
        y_test,
    }
}

impl Split {
    /// Slice a train batch (by precomputed order indices) into tensors.
    pub fn train_batch(&self, order: &[usize], step: usize, batch: usize) -> (Tensor, Tensor) {
        let mut x = vec![0.0f32; batch * self.d];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            let i = order[step * batch + b];
            x[b * self.d..(b + 1) * self.d]
                .copy_from_slice(&self.x_train[i * self.d..(i + 1) * self.d]);
            y[b] = self.y_train[i];
        }
        (Tensor::f32(&[batch, self.d], x), Tensor::i32(&[batch], y))
    }

    /// The first `n` training rows as one tensor pair (epoch-mode upload).
    pub fn train_window(&self, n: usize) -> (Tensor, Tensor) {
        assert!(n <= self.n_train, "window {n} > train size {}", self.n_train);
        (
            Tensor::f32(&[n, self.d], self.x_train[..n * self.d].to_vec()),
            Tensor::i32(&[n], self.y_train[..n].to_vec()),
        )
    }

    /// Padded eval batches: returns (tensor, valid_rows) pairs covering the
    /// test split; the tail batch repeats row 0 as padding (ignored via
    /// `valid_rows`).
    pub fn eval_batches(&self, batch: usize) -> Vec<(Tensor, Vec<i32>, usize)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.n_test {
            let valid = batch.min(self.n_test - i);
            let mut x = vec![0.0f32; batch * self.d];
            let mut y = vec![0i32; valid];
            for b in 0..batch {
                let src = if b < valid { i + b } else { 0 };
                x[b * self.d..(b + 1) * self.d]
                    .copy_from_slice(&self.x_test[src * self.d..(src + 1) * self.d]);
                if b < valid {
                    y[b] = self.y_test[i + b];
                }
            }
            out.push((Tensor::f32(&[batch, self.d], x), y, valid));
            i += valid;
        }
        out
    }

    /// Shuffled epoch order over the first `window` training rows, sized to
    /// `steps * batch` entries (cycles if needed).
    pub fn epoch_order(&self, window: usize, steps: usize, batch: usize, rng: &mut Rng) -> Vec<usize> {
        let mut order: Vec<usize> = (0..window).collect();
        rng.shuffle(&mut order);
        while order.len() < steps * batch {
            let mut extra: Vec<usize> = (0..window).collect();
            rng.shuffle(&mut extra);
            order.extend(extra);
        }
        order.truncate(steps * batch);
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{make_classification, SyntheticSpec};

    fn dataset() -> Dataset {
        make_classification(
            &SyntheticSpec { n: 120, d: 30, informative: 5, ..Default::default() },
            0,
        )
    }

    #[test]
    fn split_is_stratified_and_disjoint() {
        let ds = dataset();
        let sp = stratified_split(&ds, 0.8, 0);
        assert_eq!(sp.n_train + sp.n_test, ds.n);
        // class balance preserved within 10%
        let frac = |ys: &[i32]| ys.iter().filter(|&&y| y == 1).count() as f64 / ys.len() as f64;
        assert!((frac(&sp.y_train) - frac(&ds.y)).abs() < 0.1);
        assert!((frac(&sp.y_test) - frac(&ds.y)).abs() < 0.1);
    }

    #[test]
    fn standardization_zero_mean_unit_var() {
        let ds = dataset();
        let sp = stratified_split(&ds, 0.8, 1);
        let d = sp.d;
        for j in [0, d / 2, d - 1] {
            let vals: Vec<f64> =
                (0..sp.n_train).map(|i| sp.x_train[i * d + j] as f64).collect();
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
            assert!(mean.abs() < 1e-3, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "col {j} var {var}");
        }
    }

    #[test]
    fn log_transform_monotone_positive() {
        let mut ds = crate::data::lung::make_lung(
            &crate::data::lung::LungSpec {
                n_cases: 10,
                n_controls: 10,
                d: 20,
                informative: 3,
                ..Default::default()
            },
            0,
        );
        let before = ds.x.clone();
        log_transform(&mut ds);
        for (a, b) in ds.x.iter().zip(before.iter()) {
            assert!(*a <= *b, "log should compress large intensities");
            assert!(a.is_finite());
        }
    }

    #[test]
    fn eval_batches_cover_test_exactly_once() {
        let ds = dataset();
        let sp = stratified_split(&ds, 0.8, 2);
        let batches = sp.eval_batches(7);
        let total: usize = batches.iter().map(|(_, _, v)| v).sum();
        assert_eq!(total, sp.n_test);
        for (x, y, valid) in &batches {
            assert_eq!(x.shape(), &[7, sp.d]);
            assert_eq!(y.len(), *valid);
        }
    }

    #[test]
    fn epoch_order_covers_window() {
        let ds = dataset();
        let sp = stratified_split(&ds, 0.8, 3);
        let mut rng = Rng::new(0);
        let order = sp.epoch_order(96, 12, 8, &mut rng);
        assert_eq!(order.len(), 96);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 96, "each row exactly once when sizes match");
    }
}
