//! Data substrates for the paper's experiments.
//!
//! - [`synthetic`] — a faithful port of scikit-learn's `make_classification`
//!   generator (paper §6.1: n=1000 samples, d=10000 features, 64
//!   informative, class_sep 0.8).
//! - [`lung`]      — a *simulated* stand-in for the private LUNG
//!   metabolomics dataset of Mathe et al. (paper §6.2): 1005 urine samples
//!   (469 NSCLC / 536 control) × 2944 features with log-normal intensities,
//!   multiplicative noise and a small planted informative set. See
//!   DESIGN.md §3 for why the substitution preserves the experiment.
//! - [`loader`]    — stratified splits, standardization, log-transform,
//!   batching and shuffled epoch permutations.

pub mod loader;
pub mod lung;
pub mod synthetic;

/// A labelled dense dataset (row-major samples × features).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// n × d feature matrix, row-major.
    pub x: Vec<f32>,
    /// n labels in [0, k).
    pub y: Vec<i32>,
    pub n: usize,
    pub d: usize,
    pub k: usize,
    /// Ground-truth informative feature indices (for selection metrics);
    /// empty when unknown.
    pub informative: Vec<usize>,
}

impl Dataset {
    /// Row slice accessor.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Per-class counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.k];
        for &y in &self.y {
            counts[y as usize] += 1;
        }
        counts
    }

    /// Basic invariant check (used by tests and the loaders).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.x.len() == self.n * self.d, "x size mismatch");
        anyhow::ensure!(self.y.len() == self.n, "y size mismatch");
        anyhow::ensure!(
            self.y.iter().all(|&y| (y as usize) < self.k),
            "label out of range"
        );
        anyhow::ensure!(self.x.iter().all(|v| v.is_finite()), "non-finite feature");
        Ok(())
    }
}
