//! Port of scikit-learn's `make_classification` (paper §6.1 uses it with
//! n=1000, d=10000, 64 informative features, class separability 0.8).
//!
//! Generative process (n_clusters_per_class=1, the paper's setting):
//! 1. one centroid per class at a hypercube vertex scaled by `class_sep`
//!    in the informative subspace;
//! 2. standard-normal points around the centroid, then a random linear
//!    mixing `A (inf × inf)` to induce intra-class covariance;
//! 3. optional redundant features = random combinations of informative;
//! 4. remaining features = pure N(0,1) noise;
//! 5. label noise `flip_y`, and a random column shuffle so the informative
//!    set is hidden at random positions (returned as ground truth).

use super::Dataset;
use crate::util::rng::Rng;

/// Generation parameters (defaults follow the paper's synthetic setup).
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub n: usize,
    pub d: usize,
    pub k: usize,
    pub informative: usize,
    pub redundant: usize,
    pub class_sep: f64,
    pub flip_y: f64,
    pub shuffle: bool,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            n: 1000,
            d: 10_000,
            k: 2,
            informative: 64,
            redundant: 0,
            class_sep: 0.8,
            flip_y: 0.01,
            shuffle: true,
        }
    }
}

impl SyntheticSpec {
    /// Reduced variant matching the `synth_small` AOT config.
    pub fn small() -> Self {
        SyntheticSpec { d: 2000, ..Default::default() }
    }
}

/// Generate a dataset (deterministic per seed).
pub fn make_classification(spec: &SyntheticSpec, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5EED_DA7A);
    let SyntheticSpec { n, d, k, informative, redundant, class_sep, flip_y, shuffle } = *spec;
    assert!(informative + redundant <= d, "too many structured features");
    assert!(k >= 2);

    // 1. centroids on hypercube vertices (Gray-code style ±class_sep).
    let mut centroids = vec![0.0f64; k * informative];
    for c in 0..k {
        for j in 0..informative {
            // vertex pattern: bit j of (c * 2654435761) — deterministic,
            // distinct per class, balanced coordinates.
            let h = (c as u64).wrapping_mul(2654435761).wrapping_add(j as u64);
            let bit = (h ^ (h >> 7) ^ (h >> 13)) & 1;
            centroids[c * informative + j] = if bit == 1 { class_sep } else { -class_sep };
        }
    }

    // 2. random mixing matrix A (informative × informative).
    let mut a = vec![0.0f64; informative * informative];
    for v in a.iter_mut() {
        *v = rng.normal();
    }
    // Scale A toward orthonormal-ish so covariance stays O(1).
    let scale = 1.0 / (informative as f64).sqrt();

    // 3. redundant projection B (informative × redundant).
    let mut b = vec![0.0f64; informative * redundant];
    for v in b.iter_mut() {
        *v = rng.normal();
    }

    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0i32; n];
    let mut latent = vec![0.0f64; informative];
    let mut mixed = vec![0.0f64; informative];
    for i in 0..n {
        let c = i % k; // balanced classes
        y[i] = c as i32;
        for j in 0..informative {
            latent[j] = rng.normal();
        }
        // mixed = A·latent (scaled) + centroid
        for r in 0..informative {
            let mut acc = 0.0;
            for j in 0..informative {
                acc += a[r * informative + j] * latent[j];
            }
            mixed[r] = acc * scale + centroids[c * informative + r];
        }
        let row = &mut x[i * d..(i + 1) * d];
        for j in 0..informative {
            row[j] = mixed[j] as f32;
        }
        for j in 0..redundant {
            let mut acc = 0.0;
            for r in 0..informative {
                acc += b[r * redundant + j] * mixed[r];
            }
            row[informative + j] = (acc * scale) as f32;
        }
        for j in (informative + redundant)..d {
            row[j] = rng.normal() as f32;
        }
    }

    // 4. label noise.
    for yi in y.iter_mut() {
        if rng.chance(flip_y) {
            *yi = rng.below(k) as i32;
        }
    }

    // 5. column shuffle, tracking where the informative features land.
    let mut informative_idx: Vec<usize> = (0..informative + redundant).collect();
    if shuffle {
        let perm = rng.permutation(d); // perm[new_col] = old_col
        let mut shuffled = vec![0.0f32; n * d];
        for i in 0..n {
            let src = &x[i * d..(i + 1) * d];
            let dst = &mut shuffled[i * d..(i + 1) * d];
            for (new_c, &old_c) in perm.iter().enumerate() {
                dst[new_c] = src[old_c];
            }
        }
        x = shuffled;
        let mut where_is = vec![0usize; d]; // old_col -> new_col
        for (new_c, &old_c) in perm.iter().enumerate() {
            where_is[old_c] = new_c;
        }
        informative_idx = informative_idx.iter().map(|&c| where_is[c]).collect();
    }
    informative_idx.sort_unstable();

    Dataset { x, y, n, d, k, informative: informative_idx }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SyntheticSpec {
        SyntheticSpec { n: 200, d: 100, informative: 10, ..Default::default() }
    }

    #[test]
    fn shapes_and_validity() {
        let ds = make_classification(&small_spec(), 0);
        ds.validate().unwrap();
        assert_eq!(ds.n, 200);
        assert_eq!(ds.d, 100);
        assert_eq!(ds.informative.len(), 10);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = make_classification(&small_spec(), 3);
        let b = make_classification(&small_spec(), 3);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = make_classification(&small_spec(), 4);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn classes_balanced() {
        let ds = make_classification(&small_spec(), 1);
        let counts = ds.class_counts();
        assert!(counts.iter().all(|&c| c > 80), "{counts:?}");
    }

    #[test]
    fn informative_features_carry_signal() {
        // Mean |class-0 mean − class-1 mean| must be far larger on the
        // informative columns than on noise columns.
        let ds = make_classification(&small_spec(), 2);
        let mut gap = vec![0.0f64; ds.d];
        let mut counts = [0usize; 2];
        let mut sums = vec![[0.0f64; 2]; ds.d];
        for i in 0..ds.n {
            let c = ds.y[i] as usize;
            counts[c] += 1;
            for j in 0..ds.d {
                sums[j][c] += ds.row(i)[j] as f64;
            }
        }
        for j in 0..ds.d {
            gap[j] = (sums[j][0] / counts[0] as f64 - sums[j][1] / counts[1] as f64).abs();
        }
        let inf_set: std::collections::HashSet<_> = ds.informative.iter().copied().collect();
        let inf_gap: f64 = ds.informative.iter().map(|&j| gap[j]).sum::<f64>() / inf_set.len() as f64;
        let noise_gap: f64 = (0..ds.d).filter(|j| !inf_set.contains(j)).map(|j| gap[j]).sum::<f64>()
            / (ds.d - inf_set.len()) as f64;
        assert!(
            inf_gap > 3.0 * noise_gap,
            "informative gap {inf_gap} vs noise gap {noise_gap}"
        );
    }

    #[test]
    fn label_noise_applied() {
        let clean = make_classification(&SyntheticSpec { flip_y: 0.0, ..small_spec() }, 5);
        let noisy = make_classification(&SyntheticSpec { flip_y: 0.3, ..small_spec() }, 5);
        let flips = clean.y.iter().zip(noisy.y.iter()).filter(|(a, b)| a != b).count();
        assert!(flips > 10, "expected label flips, got {flips}");
    }
}
