//! Simulated LUNG metabolomics dataset (substitute for the private data of
//! Mathe et al. 2014 used in paper §6.2 — see DESIGN.md §3).
//!
//! The real dataset: urine samples from 469 NSCLC patients and 536 controls,
//! 2944 metabolomic features, multiplicative (log-normal) intensity noise;
//! the paper applies a log-transform before training and finds ~40
//! informative metabolites at the best radius.
//!
//! The simulation reproduces those statistics: per-feature log-normal
//! baseline intensities with heterogeneous dispersions, a planted set of
//! `informative` features whose *log-scale* means shift between classes
//! (effect sizes drawn from a half-normal, so some markers are strong and
//! some marginal), multiplicative sample-level noise (urine dilution), and
//! a small rate of missing-at-random dropouts replaced by a detection
//! floor — all standard metabolomics artifacts the pipeline must survive.

use super::Dataset;
use crate::util::rng::Rng;

/// Simulation parameters (defaults = paper's dataset statistics).
#[derive(Debug, Clone)]
pub struct LungSpec {
    pub n_cases: usize,
    pub n_controls: usize,
    pub d: usize,
    pub informative: usize,
    /// Mean absolute class shift in log-intensity units.
    pub effect_size: f64,
    /// Std of the per-sample dilution factor (log scale).
    pub dilution_sigma: f64,
    /// Probability a measurement falls below the detection floor.
    pub dropout: f64,
}

impl Default for LungSpec {
    fn default() -> Self {
        LungSpec {
            n_cases: 469,
            n_controls: 536,
            d: 2944,
            informative: 40,
            effect_size: 0.8,
            dilution_sigma: 0.25,
            dropout: 0.01,
        }
    }
}

/// Generate the simulated dataset (label 1 = NSCLC case, 0 = control).
/// Values are raw positive intensities; apply the paper's log-transform via
/// [`crate::data::loader::log_transform`] before training.
pub fn make_lung(spec: &LungSpec, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x11AB_C4E5);
    let LungSpec { n_cases, n_controls, d, informative, effect_size, dilution_sigma, dropout } =
        *spec;
    let n = n_cases + n_controls;

    // Per-feature baseline log-mean and dispersion (heteroscedastic).
    let mut base_mu = vec![0.0f64; d];
    let mut base_sigma = vec![0.0f64; d];
    for j in 0..d {
        base_mu[j] = rng.range_f64(1.0, 6.0); // intensities span decades
        base_sigma[j] = rng.range_f64(0.2, 0.8);
    }
    // Planted markers: which features shift, by how much, and the sign.
    let marker_idx = rng.sample_indices(d, informative);
    let mut shift = vec![0.0f64; d];
    for &j in &marker_idx {
        let magnitude = effect_size * (0.5 + rng.normal().abs());
        shift[j] = if rng.chance(0.5) { magnitude } else { -magnitude };
    }
    let detection_floor = 0.05f64;

    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0i32; n];
    for i in 0..n {
        let is_case = i < n_cases;
        y[i] = if is_case { 1 } else { 0 };
        let dilution = rng.normal_ms(0.0, dilution_sigma); // sample-level
        let row = &mut x[i * d..(i + 1) * d];
        for j in 0..d {
            let mut logv = rng.normal_ms(base_mu[j], base_sigma[j]) + dilution;
            if is_case {
                logv += shift[j];
            }
            let mut v = logv.exp();
            if rng.chance(dropout) {
                v = detection_floor; // below detection limit
            }
            row[j] = v as f32;
        }
    }

    // Shuffle samples so classes interleave (splits stay stratified anyway).
    let perm = rng.permutation(n);
    let mut xs = vec![0.0f32; n * d];
    let mut ys = vec![0i32; n];
    for (new_i, &old_i) in perm.iter().enumerate() {
        xs[new_i * d..(new_i + 1) * d].copy_from_slice(&x[old_i * d..(old_i + 1) * d]);
        ys[new_i] = y[old_i];
    }
    let mut informative_sorted = marker_idx;
    informative_sorted.sort_unstable();

    Dataset { x: xs, y: ys, n, d, k: 2, informative: informative_sorted }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> LungSpec {
        LungSpec { n_cases: 40, n_controls: 50, d: 200, informative: 8, ..Default::default() }
    }

    #[test]
    fn shapes_counts_positive() {
        let ds = make_lung(&small(), 0);
        ds.validate().unwrap();
        assert_eq!(ds.n, 90);
        assert_eq!(ds.class_counts(), vec![50, 40]);
        assert!(ds.x.iter().all(|&v| v > 0.0), "intensities must be positive");
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(make_lung(&small(), 7).x, make_lung(&small(), 7).x);
        assert_ne!(make_lung(&small(), 7).x, make_lung(&small(), 8).x);
    }

    #[test]
    fn markers_separate_in_log_space() {
        let ds = make_lung(&small(), 1);
        let mut t_stats = vec![0.0f64; ds.d];
        for j in 0..ds.d {
            let (mut s0, mut s1, mut q0, mut q1, mut n0, mut n1) = (0.0, 0.0, 0.0, 0.0, 0, 0);
            for i in 0..ds.n {
                let v = (ds.row(i)[j] as f64).ln();
                if ds.y[i] == 0 {
                    s0 += v;
                    q0 += v * v;
                    n0 += 1;
                } else {
                    s1 += v;
                    q1 += v * v;
                    n1 += 1;
                }
            }
            let (m0, m1) = (s0 / n0 as f64, s1 / n1 as f64);
            let v0 = q0 / n0 as f64 - m0 * m0;
            let v1 = q1 / n1 as f64 - m1 * m1;
            t_stats[j] = (m1 - m0).abs() / ((v0 / n0 as f64 + v1 / n1 as f64).sqrt() + 1e-9);
        }
        let marker_mean: f64 =
            ds.informative.iter().map(|&j| t_stats[j]).sum::<f64>() / ds.informative.len() as f64;
        let inf_set: std::collections::HashSet<_> = ds.informative.iter().copied().collect();
        let noise_mean: f64 = (0..ds.d).filter(|j| !inf_set.contains(j)).map(|j| t_stats[j]).sum::<f64>()
            / (ds.d - inf_set.len()) as f64;
        assert!(
            marker_mean > 3.0 * noise_mean,
            "markers t={marker_mean:.2} vs noise t={noise_mean:.2}"
        );
    }
}
