//! End-to-end SAE training through the full three-layer stack on the tiny
//! config: every projection mode, both exec modes, double descent.
//! Requires `make artifacts` and a `--features pjrt` build.
#![cfg(feature = "pjrt")]

use l1inf::coordinator::sweep::split_for;
use l1inf::projection::l1inf::Algorithm;
use l1inf::runtime::{Engine, Manifest};
use l1inf::sae::trainer::{ExecMode, ProjectionMode, TrainConfig, Trainer, WeightSource};

fn engine_or_skip() -> Option<Engine> {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => Some(Engine::new(m).expect("PJRT client")),
        Err(e) => {
            eprintln!("SKIP sae_integration: {e:#} — run `make artifacts`");
            None
        }
    }
}

fn base_tc() -> TrainConfig {
    TrainConfig {
        model: "tiny".into(),
        epochs: 8,
        lr: 1e-2,
        lambda: 0.1,
        projection: ProjectionMode::None,
        weights: WeightSource::Uniform,
        algo: Algorithm::InverseOrder,
        exec: ExecMode::Epoch,
        seed: 0,
        double_descent: false,
    }
}

#[test]
fn all_projection_modes_train_to_high_accuracy() {
    let Some(mut engine) = engine_or_skip() else { return };
    let split = split_for("tiny", 0).unwrap();
    for projection in [
        ProjectionMode::None,
        ProjectionMode::L1 { eta: 4.0 },
        ProjectionMode::L12 { eta: 3.0 },
        ProjectionMode::L1Inf { c: 0.6 },
        // The linear-time bi-level operator must train as well as the
        // exact projection at the same radius (arXiv:2407.16293).
        ProjectionMode::Bilevel { c: 0.6 },
        // Masked keeps values unbounded, so θ grows and the support shrinks
        // faster; on the 24-feature tiny set it needs a looser radius (the
        // masked≈projected equivalence in Tables 1-2 is a d≫100 phenomenon).
        ProjectionMode::L1InfMasked { c: 1.5 },
    ] {
        let tc = TrainConfig { projection, ..base_tc() };
        let report = Trainer::new(&mut engine, tc).unwrap().train(&split).unwrap();
        assert!(
            report.test_accuracy_pct > 70.0,
            "{}: accuracy {:.1}%",
            projection.name(),
            report.test_accuracy_pct
        );
        assert_eq!(report.epochs.len(), 8);
        // losses broadly decrease
        let first = report.epochs.first().unwrap().mean_loss;
        let last = report.epochs.last().unwrap().mean_loss;
        assert!(last < first, "{}: loss {first} -> {last}", projection.name());
        if matches!(
            projection,
            ProjectionMode::L1Inf { .. }
                | ProjectionMode::Bilevel { .. }
                | ProjectionMode::L1InfMasked { .. }
        ) {
            assert!(
                report.w1.col_sparsity_pct > 20.0,
                "{} should sparsify features, got {:.1}%",
                projection.name(),
                report.w1.col_sparsity_pct
            );
            assert!(report.final_theta > 0.0);
        }
    }
}

#[test]
fn step_and_epoch_exec_modes_agree_statistically() {
    let Some(mut engine) = engine_or_skip() else { return };
    let split = split_for("tiny", 1).unwrap();
    let mut accs = Vec::new();
    for exec in [ExecMode::Step, ExecMode::Epoch] {
        let tc = TrainConfig {
            exec,
            seed: 1,
            projection: ProjectionMode::L1Inf { c: 0.6 },
            ..base_tc()
        };
        let report = Trainer::new(&mut engine, tc).unwrap().train(&split).unwrap();
        accs.push(report.test_accuracy_pct);
    }
    // Different shuffles ⇒ not bit-identical, but both must learn.
    assert!(accs.iter().all(|&a| a > 70.0), "{accs:?}");
}

#[test]
fn l1inf_projection_constrains_the_norm_every_epoch() {
    let Some(mut engine) = engine_or_skip() else { return };
    let split = split_for("tiny", 2).unwrap();
    let c = 0.5;
    let tc = TrainConfig { projection: ProjectionMode::L1Inf { c }, seed: 2, ..base_tc() };
    let report = Trainer::new(&mut engine, tc).unwrap().train(&split).unwrap();
    assert!(
        report.w1.norm_l1inf <= c * 1.001 + 1e-6,
        "final ‖w1‖₁,∞ = {} > C = {c}",
        report.w1.norm_l1inf
    );
}

#[test]
fn masked_mode_keeps_norm_unbounded_but_support_sparse() {
    let Some(mut engine) = engine_or_skip() else { return };
    let split = split_for("tiny", 3).unwrap();
    let c = 0.5;
    let proj = Trainer::new(
        &mut engine,
        TrainConfig { projection: ProjectionMode::L1Inf { c }, seed: 3, ..base_tc() },
    )
    .unwrap()
    .train(&split)
    .unwrap();
    let masked = Trainer::new(
        &mut engine,
        TrainConfig { projection: ProjectionMode::L1InfMasked { c }, seed: 3, ..base_tc() },
    )
    .unwrap()
    .train(&split)
    .unwrap();
    // Paper Table 2: masked runs carry larger weight mass than projected.
    assert!(
        masked.w1.sum_abs > proj.w1.sum_abs,
        "masked Σ|W| {} !> projected {}",
        masked.w1.sum_abs,
        proj.w1.sum_abs
    );
    assert!(masked.w1.col_sparsity_pct > 20.0);
}

#[test]
fn double_descent_retrains_on_frozen_support() {
    let Some(mut engine) = engine_or_skip() else { return };
    let split = split_for("tiny", 4).unwrap();
    let tc = TrainConfig {
        projection: ProjectionMode::L1Inf { c: 0.6 },
        double_descent: true,
        seed: 4,
        ..base_tc()
    };
    let report = Trainer::new(&mut engine, tc).unwrap().train(&split).unwrap();
    let retrain = report.retrain_accuracy_pct.expect("double descent ran");
    assert!(retrain > 60.0, "retrain accuracy {retrain:.1}%");
}

#[test]
fn feature_selection_finds_planted_informative_features() {
    let Some(mut engine) = engine_or_skip() else { return };
    // tiny dataset plants 4 informative features among 24.
    let ds = l1inf::coordinator::dataset_for("tiny", 5).unwrap();
    let split = split_for("tiny", 5).unwrap();
    let tc = TrainConfig {
        projection: ProjectionMode::L1Inf { c: 0.4 },
        epochs: 12,
        seed: 5,
        ..base_tc()
    };
    let report = Trainer::new(&mut engine, tc).unwrap().train(&split).unwrap();
    let (_prec, recall) =
        l1inf::sae::metrics::selection_quality(&report.w1.selected, &ds.informative);
    assert!(
        recall >= 0.5,
        "selected {:?} recovers only {recall:.2} of planted {:?}",
        report.w1.selected,
        ds.informative
    );
}
