//! Integration: the PJRT runtime loads every AOT artifact and executes the
//! train/eval programs with sensible numerics. Requires `make artifacts`
//! and a `--features pjrt` build.
#![cfg(feature = "pjrt")]

use l1inf::runtime::{ArtifactKind, Engine, Manifest, Tensor};
use l1inf::sae::state::TrainState;
use l1inf::util::rng::Rng;

fn engine_or_skip() -> Option<Engine> {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => Some(Engine::new(m).expect("PJRT client")),
        Err(e) => {
            eprintln!("SKIP runtime_integration: {e:#} — run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_lists_all_kinds_for_tiny() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = engine.config("tiny").unwrap();
    for kind in ArtifactKind::ALL {
        assert!(
            cfg.artifacts.contains_key(kind.key()),
            "tiny is missing artifact kind {}",
            kind.key()
        );
    }
    assert_eq!(cfg.param_shapes[0], vec![cfg.d, cfg.hidden]);
}

#[test]
fn eval_executes_with_expected_shapes() {
    let Some(mut engine) = engine_or_skip() else { return };
    let cfg = engine.config("tiny").unwrap();
    let state = TrainState::init(&cfg, &mut Rng::new(0));
    let x = Tensor::zeros(&[cfg.eval_batch, cfg.d]);
    let mut inputs = state.params.clone();
    inputs.push(x);
    let out = engine.run("tiny", ArtifactKind::Eval, &inputs).unwrap();
    assert_eq!(out.len(), 2, "eval returns (logits, xhat)");
    assert_eq!(out[0].shape(), &[cfg.eval_batch, cfg.k]);
    assert_eq!(out[1].shape(), &[cfg.eval_batch, cfg.d]);
}

/// Build a linearly separable batch: class = sign of feature 0.
fn toy_batch(cfg: &l1inf::runtime::ModelConfig, rng: &mut Rng, n: usize) -> (Tensor, Tensor) {
    let mut x = vec![0.0f32; n * cfg.d];
    let mut y = vec![0i32; n];
    for i in 0..n {
        for j in 0..cfg.d {
            x[i * cfg.d + j] = rng.normal() as f32;
        }
        y[i] = (i % 2) as i32;
        x[i * cfg.d] += if y[i] == 1 { 2.0 } else { -2.0 };
    }
    (Tensor::f32(&[n, cfg.d], x), Tensor::i32(&[n], y))
}

#[test]
fn train_step_learns_and_returns_full_state() {
    let Some(mut engine) = engine_or_skip() else { return };
    let cfg = engine.config("tiny").unwrap();
    let mut rng = Rng::new(7);
    let mut state = TrainState::init(&cfg, &mut rng);
    let (x, y) = toy_batch(&cfg, &mut rng, cfg.batch);

    let mut first_loss = None;
    let mut last_loss = 0.0;
    for _ in 0..30 {
        let inputs = state.step_inputs(&x, &y, 1e-2, 0.1);
        let out = engine.run("tiny", ArtifactKind::Step, &inputs).unwrap();
        assert_eq!(out.len(), 27, "step returns params(8)+m(8)+v(8)+t+loss+correct");
        let (loss, correct) = state.absorb_step(out).unwrap();
        assert!(loss.is_finite());
        assert!(correct <= cfg.batch as i64);
        first_loss.get_or_insert(loss);
        last_loss = loss;
    }
    let first = first_loss.unwrap();
    assert!(
        last_loss < 0.6 * first,
        "no learning through the AOT path: {first} -> {last_loss}"
    );
    assert!((state.t - 30.0).abs() < 1e-6);
}

#[test]
fn masked_step_freezes_w1_rows() {
    let Some(mut engine) = engine_or_skip() else { return };
    let cfg = engine.config("tiny").unwrap();
    let mut rng = Rng::new(8);
    let mut state = TrainState::init(&cfg, &mut rng);
    let (x, y) = toy_batch(&cfg, &mut rng, cfg.batch);

    // Freeze the first half of the input features.
    let mut mask = vec![1.0f32; cfg.d * cfg.hidden];
    for r in 0..cfg.d / 2 {
        for c in 0..cfg.hidden {
            mask[r * cfg.hidden + c] = 0.0;
        }
    }
    let mask_t = Tensor::f32(&[cfg.d, cfg.hidden], mask);
    for _ in 0..3 {
        let mut inputs = state.step_inputs(&x, &y, 1e-2, 0.1);
        inputs.push(mask_t.clone());
        let out = engine.run("tiny", ArtifactKind::StepMasked, &inputs).unwrap();
        state.absorb_step(out).unwrap();
    }
    let w1 = state.params[0].as_f32().unwrap();
    let frozen = &w1[..(cfg.d / 2) * cfg.hidden];
    assert!(frozen.iter().all(|&v| v == 0.0), "masked rows revived");
    let live = &w1[(cfg.d / 2) * cfg.hidden..];
    assert!(live.iter().any(|&v| v != 0.0));
}

#[test]
fn epoch_scan_matches_sequential_steps() {
    let Some(mut engine) = engine_or_skip() else { return };
    let cfg = engine.config("tiny").unwrap();
    let mut rng = Rng::new(9);
    let init = TrainState::init(&cfg, &mut rng);
    let (x_all, y_all) = toy_batch(&cfg, &mut rng, cfg.n_train);
    let perm: Vec<i32> = (0..(cfg.steps_per_epoch * cfg.batch) as i32).collect();

    // Path A: epoch executable (device-resident buffers).
    let mut state_a = init.clone();
    let xb = engine.upload(&x_all).unwrap();
    let yb = engine.upload(&y_all).unwrap();
    let permb = engine.upload(&Tensor::i32(&[perm.len()], perm.clone())).unwrap();
    let (mean_loss_a, correct_a) = {
        let mut bufs = Vec::new();
        for t in state_a.flat_state().iter() {
            bufs.push(engine.upload(t).unwrap());
        }
        bufs.push(engine.upload(&Tensor::scalar_f32(state_a.t)).unwrap());
        let lr = engine.upload(&Tensor::scalar_f32(1e-2)).unwrap();
        let lam = engine.upload(&Tensor::scalar_f32(0.1)).unwrap();
        let mut refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        refs.push(&xb);
        refs.push(&yb);
        refs.push(&permb);
        refs.push(&lr);
        refs.push(&lam);
        let out = engine.run_buffers("tiny", ArtifactKind::Epoch, &refs).unwrap();
        state_a.absorb_step(out).unwrap()
    };

    // Path B: sequential steps over the same batches.
    let mut state_b = init;
    let mut losses = Vec::new();
    let mut corrects = 0i64;
    for s in 0..cfg.steps_per_epoch {
        let lo = s * cfg.batch;
        let hi = lo + cfg.batch;
        let xs = x_all.as_f32().unwrap()[lo * cfg.d..hi * cfg.d].to_vec();
        let ys = y_all.as_i32().unwrap()[lo..hi].to_vec();
        let inputs = state_b.step_inputs(
            &Tensor::f32(&[cfg.batch, cfg.d], xs),
            &Tensor::i32(&[cfg.batch], ys),
            1e-2,
            0.1,
        );
        let out = engine.run("tiny", ArtifactKind::Step, &inputs).unwrap();
        let (loss, c) = state_b.absorb_step(out).unwrap();
        losses.push(loss);
        corrects += c;
    }

    let mean_b = losses.iter().sum::<f64>() / losses.len() as f64;
    assert!((mean_loss_a - mean_b).abs() < 1e-4, "epoch {mean_loss_a} vs steps {mean_b}");
    assert_eq!(correct_a, corrects);
    for (a, b) in state_a.params.iter().zip(state_b.params.iter()) {
        let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() < 1e-4, "param divergence at {i}");
        }
    }
}

#[test]
fn tensor_literal_roundtrip() {
    let Some(_engine) = engine_or_skip() else { return };
    // f32 with shape
    let t = Tensor::f32(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 9.0, -7.25]);
    let lit = t.to_literal().unwrap();
    let back = Tensor::from_literal(&lit).unwrap();
    assert_eq!(t, back);
    // i32
    let t = Tensor::i32(&[4], vec![1, -2, 3, 4]);
    let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
    assert_eq!(t, back);
    // scalar
    let t = Tensor::scalar_f32(3.25);
    let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
    assert_eq!(t, back);
}
