//! Config-system and CLI-surface integration: file parsing + overrides +
//! typed extraction + the shipped `configs/*.toml` presets, and the
//! launcher binary's top-level commands.

use l1inf::config::train::{sweep_config, train_config};
use l1inf::config::Config;
use l1inf::sae::trainer::{ExecMode, ProjectionMode};
use std::process::Command;

#[test]
fn shipped_presets_parse_into_valid_train_configs() {
    for preset in ["configs/synth.toml", "configs/lung.toml", "configs/quickstart.toml"] {
        let cfg = Config::load(preset).unwrap_or_else(|e| panic!("{preset}: {e:#}"));
        let tc = train_config(&cfg).unwrap_or_else(|e| panic!("{preset}: {e:#}"));
        assert!(tc.epochs > 0, "{preset}");
        assert!(tc.lr > 0.0, "{preset}");
        let sweep = sweep_config(&cfg, &[1.0], &[0]);
        assert!(!sweep.radii.is_empty(), "{preset}");
    }
}

#[test]
fn override_chain_file_then_set() {
    let mut cfg = Config::load("configs/synth.toml").unwrap();
    let before = train_config(&cfg).unwrap();
    cfg.set_override("train.epochs=3").unwrap();
    cfg.set_override("train.projection=\"l21\"").unwrap();
    let after = train_config(&cfg).unwrap();
    assert_ne!(before.epochs, after.epochs);
    assert_eq!(after.epochs, 3);
    assert!(matches!(after.projection, ProjectionMode::L12 { .. }));
}

#[test]
fn exec_mode_strings() {
    for (s, expect) in [("step", ExecMode::Step), ("epoch", ExecMode::Epoch)] {
        let cfg = Config::parse(&format!("[train]\nexec = \"{s}\"\n")).unwrap();
        assert_eq!(train_config(&cfg).unwrap().exec, expect);
    }
}

fn binary() -> &'static str {
    env!("CARGO_BIN_EXE_l1inf")
}

#[test]
fn cli_help_and_unknown_command() {
    let out = Command::new(binary()).output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("usage:"));

    let out = Command::new(binary()).arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn cli_project_runs_and_reports_certificate() {
    let out = Command::new(binary())
        .args(["project", "--groups", "50", "--len", "20", "--radius", "0.5", "--algo", "inv_order"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("theta"), "{stdout}");
    assert!(stdout.contains("zero groups"));
}

#[test]
fn cli_project_all_algorithms() {
    for algo in ["bisect", "quattoni", "naive", "bejar", "newton", "inv_order"] {
        let out = Command::new(binary())
            .args(["project", "--groups", "30", "--len", "10", "--radius", "0.3", "--algo", algo])
            .output()
            .unwrap();
        assert!(out.status.success(), "algo {algo}: {}", String::from_utf8_lossy(&out.stderr));
    }
}

#[test]
fn cli_artifacts_lists_configs() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP cli_artifacts_lists_configs — run `make artifacts`");
        return;
    }
    let out = Command::new(binary()).arg("artifacts").output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("tiny"), "{stdout}");
    assert!(stdout.contains("synth"));
}

#[test]
fn cli_exp_rejects_unknown_experiment() {
    let out = Command::new(binary()).args(["exp", "fig99"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown experiment"));
}
