//! Cross-algorithm equivalence: all six ℓ₁,∞ solvers must produce the same
//! θ* and the same projected matrix, across adversarial random inputs,
//! structured corner cases, and paper-scale shapes.

use l1inf::projection::l1inf::{project_l1inf, solve_theta, Algorithm};
use l1inf::projection::{norm_l1inf, sparsity_pct, GroupedView};
use l1inf::util::prop;
use l1inf::util::rng::Rng;

fn all_solvers_agree(data: &[f32], g: usize, l: usize, c: f64) -> Result<(), String> {
    let norm = norm_l1inf(GroupedView::new(data, g, l));
    if norm <= c || c <= 0.0 {
        return Ok(());
    }
    let abs: Vec<f32> = data.iter().map(|v| v.abs()).collect();
    let gold = solve_theta(&abs, g, l, c, Algorithm::Bisection);
    let scale = gold.theta.abs().max(1.0);
    for algo in Algorithm::ALL {
        let st = solve_theta(&abs, g, l, c, algo);
        if (st.theta - gold.theta).abs() > 1e-5 * scale {
            return Err(format!(
                "{}: theta {} != gold {} (g={g} l={l} c={c})",
                algo.name(),
                st.theta,
                gold.theta
            ));
        }
    }
    // Projected matrices must agree elementwise too.
    let mut reference = data.to_vec();
    project_l1inf(&mut reference, g, l, c, Algorithm::Bisection);
    for algo in Algorithm::ALL {
        let mut out = data.to_vec();
        project_l1inf(&mut out, g, l, c, algo);
        for i in 0..out.len() {
            if (out[i] - reference[i]).abs() > 1e-4 {
                return Err(format!(
                    "{}: element {i} differs: {} vs {}",
                    algo.name(),
                    out[i],
                    reference[i]
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn random_matrices_all_algorithms_agree() {
    prop::check(
        "six solvers agree on random signed matrices",
        300,
        0xE0,
        |rng: &mut Rng| {
            let (mut data, g, l) = prop::gen_projection_matrix(rng, 12, 16);
            for v in data.iter_mut() {
                if rng.chance(0.5) {
                    *v = -*v;
                }
            }
            let norm = norm_l1inf(GroupedView::new(&data, g, l));
            let c = rng.f64() * 1.2 * norm.max(0.1);
            (data, g, l, c)
        },
        |(data, g, l, c)| all_solvers_agree(data, *g, *l, *c),
    );
}

#[test]
fn single_group_reduces_to_clip() {
    // m = 1: the projection clips the single group so its max equals C.
    let mut y = vec![3.0f32, -2.0, 1.0, 0.5];
    let info = project_l1inf(&mut y, 1, 4, 1.5, Algorithm::InverseOrder);
    assert!((info.radius_after - 1.5).abs() < 1e-5);
    assert!(y.iter().all(|v| v.abs() <= 1.5 + 1e-6));
    assert_eq!(y[1], -1.5, "clip preserves sign");
}

#[test]
fn single_element_groups_reduce_to_l1_ball() {
    // group_len = 1: ℓ₁,∞ over singleton groups IS the ℓ₁ ball.
    let mut rng = Rng::new(3);
    let mut y = vec![0.0f32; 64];
    for v in y.iter_mut() {
        *v = (rng.f32() - 0.5) * 4.0;
    }
    let mut via_l1inf = y.clone();
    project_l1inf(&mut via_l1inf, 64, 1, 2.0, Algorithm::InverseOrder);
    let mut via_l1 = y.clone();
    l1inf::projection::l1::project_l1(&mut via_l1, 2.0);
    for i in 0..64 {
        assert!((via_l1inf[i] - via_l1[i]).abs() < 1e-5, "at {i}");
    }
}

#[test]
fn paper_scale_uniform_matrix() {
    // The Fig-1 configuration (reduced reps): 1000×1000 U[0,1), C = 1.
    let (n, m) = (1000, 1000);
    let mut rng = Rng::new(0xF1);
    let mut data = vec![0.0f32; n * m];
    rng.fill_uniform_f32(&mut data);
    let abs = data.clone();
    let gold = solve_theta(&abs, m, n, 1.0, Algorithm::Newton);
    for algo in [Algorithm::InverseOrder, Algorithm::Bejar, Algorithm::Quattoni] {
        let st = solve_theta(&abs, m, n, 1.0, algo);
        assert!(
            (st.theta - gold.theta).abs() < 1e-5 * gold.theta.max(1.0),
            "{}: {} vs {}",
            algo.name(),
            st.theta,
            gold.theta
        );
    }
    let mut out = data;
    let info = project_l1inf(&mut out, m, n, 1.0, Algorithm::InverseOrder);
    assert!((info.radius_after - 1.0).abs() < 1e-3);
    // Measured: C=1 on 1000 uniform columns zeroes ~80% of entries.
    assert!(sparsity_pct(&out) > 70.0, "C=1 on 1000 uniform columns is sparse");
}

#[test]
fn idempotence_across_algorithms() {
    prop::check(
        "projection is idempotent",
        100,
        0xE1,
        |rng: &mut Rng| {
            let (data, g, l) = prop::gen_projection_matrix(rng, 8, 10);
            let c = rng.f64() * 2.0 + 0.01;
            let algo = Algorithm::ALL[rng.below(Algorithm::ALL.len())];
            (data, g, l, c, algo)
        },
        |(data, g, l, c, algo)| {
            let mut once = data.clone();
            project_l1inf(&mut once, *g, *l, *c, *algo);
            let mut twice = once.clone();
            let info = project_l1inf(&mut twice, *g, *l, *c, *algo);
            if !info.feasible && info.theta > 1e-6 {
                for i in 0..once.len() {
                    if (once[i] - twice[i]).abs() > 1e-4 {
                        return Err(format!("not idempotent at {i}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn work_counters_reflect_sparsity_regimes() {
    // Inverse order must touch few groups when C is tight and many when
    // loose — the J-vs-K asymmetry that motivates the paper.
    let (n, m) = (64, 400);
    let mut rng = Rng::new(77);
    let mut data = vec![0.0f32; n * m];
    rng.fill_uniform_f32(&mut data);
    let abs = data;
    let tight = solve_theta(&abs, m, n, 0.5, Algorithm::InverseOrder);
    let loose = solve_theta(&abs, m, n, 0.95 * norm_l1inf(GroupedView::new(&abs, m, n)), Algorithm::InverseOrder);
    assert!(
        tight.touched_groups < loose.touched_groups,
        "tight {} !< loose {}",
        tight.touched_groups,
        loose.touched_groups
    );
    assert!(tight.work < loose.work, "tight work {} !< loose {}", tight.work, loose.work);
}
