//! The metrics plane end to end: a mixed exact/bilevel/weighted TCP
//! session whose stats counters reconcile *exactly* with the traffic sent,
//! histogram snapshots that stay monotone, work terms that are nonzero
//! only when a real (infeasible) solve ran, error responses that echo the
//! request's parseable mode, and the `--metrics-snapshot` file written on
//! an interval and at shutdown.
//!
//! Plus the tracing plane: a trace-enabled session whose `{"op":"trace"}`
//! drain decomposes every request into a well-formed span tree, and a
//! disabled recorder that stays empty.
//!
//! The registry is process-global, so the tests that issue counted
//! `project`/`delta` ops ([`stats_reconcile_exactly_with_traffic`],
//! [`traced_session_drains_well_formed_span_trees`]) serialize on
//! [`COUNTED_TRAFFIC`] — the snapshot-file test sticks to
//! `ping`/`stats`/`shutdown` to keep the per-family solve counters
//! attributable to one test.

use l1inf::config::serve::ServeConfig;
use l1inf::serve::server::Server;
use l1inf::util::json::{self, Json};
use l1inf::util::trace;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// The solve/op counters (and the trace recorder's enabled flag) are
/// process-global, so the tests that issue counted `project`/`delta`
/// traffic serialize on this lock to keep their before/after deltas
/// attributable. Poisoning is ignored: a failed sibling must not mask
/// this test's own verdict.
static COUNTED_TRAFFIC: std::sync::Mutex<()> = std::sync::Mutex::new(());

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connecting to test server");
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn roundtrip(&mut self, line: &str) -> Json {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response '{resp}': {e}"))
    }

    fn stats(&mut self, id: u32) -> Json {
        let resp = self.roundtrip(&format!(r#"{{"id": {id}, "op": "stats"}}"#));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        resp
    }
}

/// A 3×4 matrix with ‖·‖₁,∞ = 3.0 (group maxes 1.0, 0.9, 1.1).
const DATA: &str = "1.0,-0.5,0.25,0.0,0.9,0.8,-0.7,0.1,1.1,0.2,0.3,-0.4";

fn project_line(id: u32, mode_field: &str, key: Option<&str>, radius: f64) -> String {
    let key_field = key.map(|k| format!(r#""key": "{k}", "#)).unwrap_or_default();
    format!(
        r#"{{"id": {id}, "op": "project", {mode_field}{key_field}"groups": 3, "len": 4, "radius": {radius}, "data": [{DATA}]}}"#
    )
}

fn counter(stats: &Json, name: &str) -> f64 {
    stats
        .get("metrics")
        .and_then(|m| m.get("counters"))
        .and_then(|c| c.get(name))
        .and_then(Json::as_f64)
        .unwrap_or(0.0)
}

fn hist_field(stats: &Json, hist: &str, field: &str) -> f64 {
    stats
        .get("metrics")
        .and_then(|m| m.get("histograms"))
        .and_then(|h| h.get(hist))
        .and_then(|h| h.get(field))
        .and_then(Json::as_f64)
        .unwrap_or(0.0)
}

fn cache_field(stats: &Json, family: &str, field: &str) -> f64 {
    stats
        .get("cache")
        .and_then(|c| c.get(family))
        .and_then(|f| f.get(field))
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("stats missing cache.{family}.{field}: {stats}"))
}

#[test]
fn stats_reconcile_exactly_with_traffic() {
    let _lock = COUNTED_TRAFFIC.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), threads: 2, ..Default::default() };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());
    let mut client = Client::connect(addr);

    let before = client.stats(1);

    // ── traffic ─────────────────────────────────────────────────────────
    // 3 infeasible exact solves under one key: 1 cold, then 2 warm.
    for id in [10, 11, 12] {
        let resp = client.roundtrip(&project_line(id, "", Some("obs"), 1.5));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(resp.get("feasible"), Some(&Json::Bool(false)));
        assert_eq!(resp.get("warm"), Some(&Json::Bool(id != 10)), "{resp}");
    }
    // 2 infeasible bilevel solves under the same client key (own family
    // namespace): cold, then warm.
    for id in [20, 21] {
        let resp = client.roundtrip(&project_line(id, r#""mode": "bilevel", "#, Some("obs"), 1.5));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(resp.get("mode").unwrap().as_str(), Some("bilevel"));
        assert_eq!(resp.get("warm"), Some(&Json::Bool(id != 20)), "{resp}");
    }
    // 2 infeasible weighted solves: cold, then warm.
    for id in [30, 31] {
        let line = project_line(id, r#""mode": "weighted", "#, Some("obs"), 1.5)
            .replace(r#""data""#, r#""weights": [1.0, 2.0, 0.5], "data""#);
        let resp = client.roundtrip(&line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(resp.get("mode").unwrap().as_str(), Some("weighted"));
        assert_eq!(resp.get("warm"), Some(&Json::Bool(id != 30)), "{resp}");
    }

    let mid = client.stats(2);

    // 2 feasible exact requests (radius far above the norm, no key): they
    // count as solves but no θ search runs — the work term must stay 0.
    for id in [40, 41] {
        let resp = client.roundtrip(&project_line(id, "", None, 100.0));
        assert_eq!(resp.get("feasible"), Some(&Json::Bool(true)), "{resp}");
    }

    // One malformed project whose mode parses: the error must echo it.
    let err = client.roundtrip(r#"{"id": 50, "op": "project", "mode": "bilevel", "groups": 2}"#);
    assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(err.get("mode").unwrap().as_str(), Some("bilevel"), "{err}");
    // ...and one whose mode is unparseable: no mode field at all.
    let err2 = client.roundtrip(r#"{"id": 51, "op": "project", "mode": "warp", "groups": 2}"#);
    assert_eq!(err2.get("ok"), Some(&Json::Bool(false)));
    assert!(err2.get("mode").is_none(), "{err2}");

    // ── delta traffic (incremental projection, exact namespace) ─────────
    // Init seeds the keyed state with the full matrix. `begin` is setup,
    // not an incremental solve, so no delta_* counters move here.
    let row0 = "1.0,-0.5,0.25,0.0";
    let init = client.roundtrip(&format!(
        r#"{{"id": 60, "op": "delta", "key": "dobs", "init": true, "groups": 3, "len": 4, "radius": 1.5, "data": [{DATA}]}}"#
    ));
    assert_eq!(init.get("ok"), Some(&Json::Bool(true)), "{init}");
    assert_eq!(init.get("warm"), Some(&Json::Bool(false)), "{init}");
    assert_eq!(init.get("fallback"), Some(&Json::Bool(false)), "{init}");
    // Re-sending group 0 unchanged repairs exactly that one declared
    // group: every undeclared clip level is bit-identical, so nothing
    // else is rewritten — the counter increment is deterministic.
    let inc = client.roundtrip(&format!(
        r#"{{"id": 61, "op": "delta", "key": "dobs", "groups": 3, "len": 4, "radius": 1.5, "rows": [0], "data": [{row0}]}}"#
    ));
    assert_eq!(inc.get("ok"), Some(&Json::Bool(true)), "{inc}");
    assert_eq!(inc.get("warm"), Some(&Json::Bool(true)), "{inc}");
    assert_eq!(inc.get("fallback"), Some(&Json::Bool(false)), "{inc}");
    assert_eq!(inc.get("repaired"), Some(&Json::Num(1.0)), "{inc}");
    // Declaring 2 of 3 groups crosses the oversized-delta fraction:
    // deterministic certified cold fallback repairing all 3 groups.
    let fb = client.roundtrip(&format!(
        r#"{{"id": 62, "op": "delta", "key": "dobs", "groups": 3, "len": 4, "radius": 1.5, "rows": [0, 1], "data": [{row0}, 0.9, 0.8, -0.7, 0.1]}}"#
    ));
    assert_eq!(fb.get("ok"), Some(&Json::Bool(true)), "{fb}");
    assert_eq!(fb.get("fallback"), Some(&Json::Bool(true)), "{fb}");
    assert_eq!(fb.get("repaired"), Some(&Json::Num(3.0)), "{fb}");

    // Typed delta errors — never a silent cold solve. A key with no
    // persisted state:
    let ghost = client.roundtrip(&format!(
        r#"{{"id": 63, "op": "delta", "key": "ghost", "groups": 3, "len": 4, "radius": 1.5, "rows": [0], "data": [{row0}]}}"#
    ));
    assert_eq!(ghost.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(ghost.get("mode").unwrap().as_str(), Some("exact"), "{ghost}");
    assert!(
        ghost.get("error").unwrap().as_str().unwrap().contains("no persisted state"),
        "{ghost}"
    );
    // A shape that disagrees with the persisted 3×4 state:
    let shape = client.roundtrip(&format!(
        r#"{{"id": 64, "op": "delta", "key": "dobs", "groups": 2, "len": 4, "radius": 1.5, "rows": [0], "data": [{row0}]}}"#
    ));
    assert_eq!(shape.get("ok"), Some(&Json::Bool(false)));
    assert!(
        shape.get("error").unwrap().as_str().unwrap().contains("re-send with \"init\":true"),
        "{shape}"
    );
    // A radius the persisted solver is not tracking:
    let rad = client.roundtrip(&format!(
        r#"{{"id": 65, "op": "delta", "key": "dobs", "groups": 3, "len": 4, "radius": 2.0, "rows": [0], "data": [{row0}]}}"#
    ));
    assert_eq!(rad.get("ok"), Some(&Json::Bool(false)));
    assert!(rad.get("error").unwrap().as_str().unwrap().contains("radius"), "{rad}");
    // A non-exact family namespace is rejected at parse time with the
    // family echoed (only the exact family keeps incremental state).
    let ns = client.roundtrip(&format!(
        r#"{{"id": 66, "op": "delta", "key": "dobs", "mode": "bilevel", "groups": 3, "len": 4, "radius": 1.5, "rows": [0], "data": [{row0}]}}"#
    ));
    assert_eq!(ns.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(ns.get("mode").unwrap().as_str(), Some("bilevel"), "{ns}");
    assert!(
        ns.get("error").unwrap().as_str().unwrap().contains("keeps no incremental state"),
        "{ns}"
    );

    let after = client.stats(3);

    // ── exact reconciliation against the traffic above ──────────────────
    let d = |name: &str| counter(&after, name) - counter(&before, name);
    assert_eq!(
        d("solve.exact.count"),
        5.0,
        "3 infeasible + 2 feasible exact solves; delta ops must not inflate it"
    );
    assert_eq!(d("solve.bilevel.count"), 2.0);
    assert_eq!(d("solve.weighted.count"), 2.0);
    assert_eq!(d("serve.op.project"), 9.0);
    assert_eq!(d("serve.op.delta"), 6.0, "3 served + 3 typed-error delta requests");
    assert_eq!(d("serve.op.error"), 6.0, "2 project parse + 3 typed delta + 1 delta parse");
    // Admission control: an uncontended session (default in-flight cap)
    // accepts every line and sheds none.
    assert_eq!(d("serve.admission.shed"), 0.0, "nothing sheds below the in-flight cap");
    assert!(
        d("serve.admission.accepted") >= d("serve.op.project") + d("serve.op.delta"),
        "every dispatched line was admitted first"
    );
    // Delta counters reconcile against the responses above: the identical
    // re-send repaired 1 group, the oversized fallback repaired all 3 (and
    // is the only fallback); init records nothing.
    assert_eq!(d("solve.exact.delta_repaired_groups"), 4.0);
    assert_eq!(d("solve.exact.delta_fallback"), 1.0);
    // Per family: one cold miss, the rest of the keyed lookups hit; every
    // infeasible solve updates its namespace. The 3 successful delta ops
    // publish θ into the exact namespace too, but never read the hint
    // cache — no extra hits or misses.
    let cd = |family: &str, field: &str| {
        cache_field(&after, family, field) - cache_field(&before, family, field)
    };
    assert_eq!(cd("exact", "misses"), 1.0);
    assert_eq!(cd("exact", "hits"), 2.0);
    assert_eq!(cd("exact", "updates"), 6.0);
    assert_eq!(cd("bilevel", "misses"), 1.0);
    assert_eq!(cd("bilevel", "hits"), 1.0);
    assert_eq!(cd("bilevel", "updates"), 2.0);
    assert_eq!(cd("weighted", "misses"), 1.0);
    assert_eq!(cd("weighted", "hits"), 1.0);
    assert_eq!(cd("weighted", "updates"), 2.0);
    assert_eq!(cd("total", "hits"), 4.0);
    // Served = successful project + delta responses (typed delta errors
    // count under serve.op.error instead); uptime moves forward.
    let served_of = |s: &Json| s.get("served").unwrap().as_f64().unwrap();
    assert_eq!(served_of(&after) - served_of(&before), 12.0);
    assert!(
        after.get("uptime_secs").unwrap().as_f64().unwrap()
            >= before.get("uptime_secs").unwrap().as_f64().unwrap()
    );
    // Hinted-solve accounting: exactly the 2 warm exact solves were hinted
    // (feasible solves never consult the hint), split between accept and
    // reject by the solver's own verdict.
    let hinted = d("solve.exact.hint_accept") + d("solve.exact.hint_reject");
    assert_eq!(hinted, 2.0);
    assert!(d("solve.exact.hint_accept") >= 1.0, "same-matrix hints should be accepted");

    // ── work term: nonzero only when a real solve ran ───────────────────
    let wd = |a: &Json, b: &Json, name: &str| {
        hist_field(a, name, "sum") - hist_field(b, name, "sum")
    };
    assert!(wd(&mid, &before, "solve.exact.work") > 0.0, "cold infeasible solves do work");
    assert_eq!(
        wd(&after, &mid, "solve.exact.work"),
        0.0,
        "feasible projections must record zero work"
    );
    let work = "solve.exact.work";
    assert_eq!(hist_field(&after, work, "count") - hist_field(&mid, work, "count"), 2.0);

    // ── histogram snapshots are monotone ────────────────────────────────
    let hists = after.get("metrics").unwrap().get("histograms").unwrap().as_obj().unwrap();
    assert!(hists.contains_key("serve.request.latency_us"));
    assert!(hists.contains_key("solve.exact.latency_us"));
    for (name, h) in hists {
        let count = h.get("count").and_then(Json::as_f64).unwrap();
        let cum = h.get("cumulative").and_then(Json::as_arr).unwrap();
        let mut prev = 0.0;
        for c in cum {
            let c = c.as_f64().unwrap();
            assert!(c >= prev, "{name}: cumulative buckets must be nondecreasing");
            prev = c;
        }
        if count > 0.0 {
            assert_eq!(prev, count, "{name}: cumulative must end at count");
        }
        let (p50, p90, p99) = (
            h.get("p50").and_then(Json::as_f64).unwrap(),
            h.get("p90").and_then(Json::as_f64).unwrap(),
            h.get("p99").and_then(Json::as_f64).unwrap(),
        );
        assert!(p50 <= p90 && p90 <= p99, "{name}: quantiles must be ordered");
    }

    let bye = client.roundtrip(r#"{"id": 99, "op": "shutdown"}"#);
    assert_eq!(bye.get("shutting_down"), Some(&Json::Bool(true)));
    handle.join().expect("server thread").expect("server run");
}

#[test]
fn traced_session_drains_well_formed_span_trees() {
    let _lock = COUNTED_TRAFFIC.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        threads: 2,
        trace: true,
        ..Default::default()
    };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());
    let mut client = Client::connect(addr);

    // ── mixed traced traffic: every response is stamped with its id ─────
    // (trace_id, solver-phase prefix the span tree must contain)
    let mut ids: Vec<(u64, &str)> = Vec::new();
    let mut traced = |client: &mut Client, line: &str, prefix: &'static str| {
        let resp = client.roundtrip(line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let tid = resp
            .get("trace")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("traced response missing trace id: {resp}"))
            as u64;
        ids.push((tid, prefix));
    };
    traced(&mut client, &project_line(10, "", None, 1.5), "exact.");
    traced(&mut client, &project_line(11, r#""mode": "bilevel", "#, None, 1.5), "bilevel.");
    let weighted = project_line(12, r#""mode": "weighted", "#, None, 1.5)
        .replace(r#""data""#, r#""weights": [1.0, 2.0, 0.5], "data""#);
    traced(&mut client, &weighted, "weighted.");
    let row0 = "1.0,-0.5,0.25,0.0";
    traced(
        &mut client,
        &format!(
            r#"{{"id": 13, "op": "delta", "key": "tobs", "init": true, "groups": 3, "len": 4, "radius": 1.5, "data": [{DATA}]}}"#
        ),
        "serve.", // init is a cold full solve; only the serve spans are guaranteed
    );
    traced(
        &mut client,
        &format!(
            r#"{{"id": 14, "op": "delta", "key": "tobs", "groups": 3, "len": 4, "radius": 1.5, "rows": [0], "data": [{row0}]}}"#
        ),
        "delta.",
    );
    assert_eq!(
        ids.iter().map(|(t, _)| *t).collect::<std::collections::BTreeSet<_>>().len(),
        ids.len(),
        "trace ids must be unique per request"
    );

    // ── drain the flight recorder through the wire protocol ─────────────
    let drain = client.roundtrip(r#"{"id": 90, "op": "trace", "clear": true}"#);
    assert_eq!(drain.get("ok"), Some(&Json::Bool(true)), "{drain}");
    assert_eq!(drain.get("enabled"), Some(&Json::Bool(true)), "{drain}");
    let snap = trace::snapshot_from_json(&drain).expect("trace drain parses as a snapshot");
    assert_eq!(snap.dropped, 0, "this tiny session cannot overflow the ring");

    // Span counts reconcile: one serve.request root per traced request.
    let my_roots = snap
        .events
        .iter()
        .filter(|e| e.parent == 0 && ids.iter().any(|(t, _)| *t == e.trace))
        .count();
    assert_eq!(my_roots, ids.len(), "one root span per request sent");

    for &(tid, prefix) in &ids {
        let evs: Vec<&trace::Event> =
            snap.events.iter().filter(|e| e.trace == tid).collect();
        let names = || evs.iter().map(|e| e.name).collect::<Vec<_>>();
        let count = |n: &str| evs.iter().filter(|e| e.name == n).count();

        // Exactly one root, and it is the request envelope.
        let roots: Vec<_> = evs.iter().filter(|e| e.parent == 0).collect();
        assert_eq!(roots.len(), 1, "trace {tid}: want 1 root, got {:?}", names());
        let root = roots[0];
        assert_eq!(root.name, "serve.request", "trace {tid}");
        assert_eq!(count("serve.parse"), 1, "trace {tid}: {:?}", names());
        assert_eq!(count("serve.respond"), 1, "trace {tid}: {:?}", names());
        assert!(
            evs.iter().any(|e| e.name.starts_with(prefix)),
            "trace {tid}: no {prefix}* phase span in {:?}",
            names()
        );

        // The tree is well-formed: span ids unique, no orphan parents,
        // every child interval inside the root's (±2µs for the
        // independent floor-to-µs of start and duration).
        let spans: std::collections::BTreeSet<u64> = evs.iter().map(|e| e.span).collect();
        assert_eq!(spans.len(), evs.len(), "trace {tid}: span ids must be unique");
        let root_end = root.start_us + root.dur_us;
        for e in &evs {
            if e.parent == 0 {
                continue;
            }
            assert!(
                spans.contains(&e.parent),
                "trace {tid}: span {} ({}) has orphan parent {}",
                e.span,
                e.name,
                e.parent
            );
            assert!(
                e.start_us >= root.start_us && e.start_us + e.dur_us <= root_end + 2,
                "trace {tid}: {} [{}..{}] escapes root [{}..{}]",
                e.name,
                e.start_us,
                e.start_us + e.dur_us,
                root.start_us,
                root_end
            );
        }

        // The renderer agrees the tree is connected.
        let rendered = trace::render_trace_from(&snap, tid).expect("renderable");
        assert!(rendered.starts_with("serve.request"), "trace {tid}:\n{rendered}");
    }

    // `clear: true` forgot everything: a second drain holds none of ours.
    let drain2 = client.roundtrip(r#"{"id": 91, "op": "trace"}"#);
    let snap2 = trace::snapshot_from_json(&drain2).expect("second drain parses");
    for &(tid, _) in &ids {
        assert!(
            snap2.events.iter().all(|e| e.trace != tid),
            "clear=true must forget trace {tid}"
        );
    }

    let bye = client.roundtrip(r#"{"id": 99, "op": "shutdown"}"#);
    assert_eq!(bye.get("shutting_down"), Some(&Json::Bool(true)));
    handle.join().expect("server thread").expect("server run");

    // ── disabled mode records zero events ───────────────────────────────
    trace::set_enabled(false);
    let probe = trace::next_trace_id();
    {
        let _root = trace::begin(probe, "disabled.probe");
        let _child = l1inf::trace_span!("disabled.child");
    }
    assert!(
        trace::snapshot().events.iter().all(|e| e.trace != probe),
        "a disabled recorder must stay empty"
    );
    // ...and an untraced server stamps no trace ids on its responses.
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), threads: 1, ..Default::default() };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());
    let mut client = Client::connect(addr);
    let resp = client.roundtrip(&project_line(70, "", None, 1.5));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
    assert!(resp.get("trace").is_none(), "untraced serve must not stamp ids: {resp}");
    let bye = client.roundtrip(r#"{"id": 99, "op": "shutdown"}"#);
    assert_eq!(bye.get("shutting_down"), Some(&Json::Bool(true)));
    handle.join().expect("server thread").expect("server run");
}

#[test]
fn snapshot_file_is_written_on_interval_and_at_shutdown() {
    let path = std::env::temp_dir()
        .join(format!("l1inf_obs_snapshot_{}.json", std::process::id()));
    std::fs::remove_file(&path).ok();
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        threads: 1,
        metrics_snapshot: Some(path.to_string_lossy().into_owned()),
        metrics_interval_secs: 0.25,
        ..Default::default()
    };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());
    let mut client = Client::connect(addr);
    let pong = client.roundtrip(r#"{"id": 1, "op": "ping"}"#);
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));

    // The interval writer must produce the file without any shutdown.
    // `fs::write` truncates before writing, so a poll can catch a half
    // rewrite — keep polling until a complete document parses.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let snap = loop {
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(snap) = json::parse(&text) {
                break snap;
            }
        }
        assert!(
            std::time::Instant::now() < deadline,
            "interval writer never produced a parseable snapshot"
        );
        std::thread::sleep(std::time::Duration::from_millis(50));
    };
    for key in ["threads", "served", "uptime_secs", "cache", "metrics"] {
        assert!(snap.get(key).is_some(), "snapshot missing {key}");
    }

    // Shutdown rewrites it (fresh uptime ≥ the interval write's).
    let t1 = snap.get("uptime_secs").unwrap().as_f64().unwrap();
    std::fs::remove_file(&path).unwrap();
    let bye = client.roundtrip(r#"{"id": 2, "op": "shutdown"}"#);
    assert_eq!(bye.get("shutting_down"), Some(&Json::Bool(true)));
    handle.join().expect("server thread").expect("server run");
    let text = std::fs::read_to_string(&path).expect("shutdown snapshot written");
    let snap = json::parse(&text).expect("shutdown snapshot parses");
    assert!(snap.get("uptime_secs").unwrap().as_f64().unwrap() >= t1);
    // The warm-start field the bench gate keys on is always present.
    for family in ["exact", "bilevel", "weighted", "total"] {
        assert!(
            snap.get("cache").unwrap().get(family).unwrap().get("hit_rate").is_some(),
            "snapshot cache.{family}.hit_rate missing"
        );
    }
    std::fs::remove_file(&path).ok();
}
