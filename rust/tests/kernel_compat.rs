//! Scalar-vs-SIMD compatibility of the dense kernel layer, end to end:
//!
//! 1. **Dispatch contract** — `L1INF_FORCE_SCALAR` resolves to the scalar
//!    path, everything else to the detected best path.
//! 2. **Projection-level agreement** — every exact solver
//!    (`Algorithm::ALL`) and the bi-level operator, run on adversarial
//!    inputs (group lengths off the 8-lane width, cross-group ties,
//!    denormals, whole-zero groups, signed zeros), must agree between the
//!    forced-scalar and dispatched kernel paths to ≤1e-6 — and bit-exactly
//!    wherever the kernels only differ by the documented f64 accumulator
//!    tree (per-group maxima, clamps, hence the whole bi-level operator
//!    and `norm_l1inf`).
//! 3. **Cross-layout bit-identity per dispatch** — a strided column view
//!    and an explicitly transposed contiguous copy produce bit-identical
//!    projections under *each* dispatch, because the lane-8 contract
//!    assigns accumulator lanes by element index, not by memory layout.

mod common;

use common::adversarial_matrix;
use l1inf::projection::bilevel::project_bilevel;
use l1inf::projection::dense::{self, Dispatch};
use l1inf::projection::grouped::{GroupedView, GroupedViewMut};
use l1inf::projection::l1inf::{new_solver, project_l1inf, project_with, Algorithm};
use l1inf::projection::{norm_l1inf, norm_l12, norm_linf1};
use l1inf::util::rng::Rng;

/// Run `f` with the calling thread pinned to `d`, restoring the default
/// dispatch afterwards even on panic.
fn with_dispatch<T>(d: Dispatch, f: impl FnOnce() -> T) -> T {
    struct Reset;
    impl Drop for Reset {
        fn drop(&mut self) {
            dense::force_dispatch_for_thread(None);
        }
    }
    dense::force_dispatch_for_thread(Some(d));
    let _r = Reset;
    f()
}

/// Every dispatch actually runnable on this machine.
fn runnable_dispatches() -> Vec<Dispatch> {
    let mut ds = vec![Dispatch::Scalar, Dispatch::Portable];
    if Dispatch::detect() == Dispatch::Avx2 {
        ds.push(Dispatch::Avx2);
    }
    ds
}

/// Lane-hostile shapes: group lengths straddling the 8-lane width,
/// single-element groups, single-group matrices.
const SHAPES: [(usize, usize); 6] = [(5, 9), (13, 1), (1, 17), (40, 7), (8, 33), (20, 16)];

#[test]
fn force_scalar_env_contract() {
    assert_eq!(Dispatch::resolve(true), Dispatch::Scalar);
    let best = Dispatch::resolve(false);
    assert_ne!(best, Dispatch::Scalar);
    assert_eq!(best, Dispatch::detect());
    // The process-wide selection is one of the three named paths, and the
    // bench-meta stamp uses exactly its name.
    assert!(matches!(dense::kernel_name(), "avx2" | "portable" | "scalar"));
    assert_eq!(dense::kernel_name(), Dispatch::active().name());
}

#[test]
fn every_exact_solver_agrees_between_scalar_and_dispatched_paths() {
    let mut rng = Rng::new(0xFC01);
    for &(g, l) in &SHAPES {
        let data = adversarial_matrix(&mut rng, g, l);
        let norm = with_dispatch(Dispatch::Scalar, || norm_l1inf(GroupedView::new(&data, g, l)));
        if norm <= 1e-9 {
            continue;
        }
        for c in [0.2 * norm, 0.7 * norm] {
            for algo in Algorithm::ALL {
                let mut scalar = data.clone();
                let si = with_dispatch(Dispatch::Scalar, || {
                    project_l1inf(&mut scalar, g, l, c, algo)
                });
                let mut dispatched = data.clone();
                let di = project_l1inf(&mut dispatched, g, l, c, algo);
                let scale = si.theta.abs().max(1.0);
                assert!(
                    (si.theta - di.theta).abs() <= 1e-6 * scale,
                    "{} {g}x{l} c={c}: θ scalar {} vs dispatched {}",
                    algo.name(),
                    si.theta,
                    di.theta
                );
                for (i, (a, b)) in scalar.iter().zip(&dispatched).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-6,
                        "{} {g}x{l} c={c}: element {i}: {a} vs {b}",
                        algo.name()
                    );
                }
                assert_eq!(si.zero_groups, di.zero_groups, "{} {g}x{l} c={c}", algo.name());
            }
        }
    }
}

#[test]
fn bilevel_operator_is_bit_exact_between_scalar_and_dispatched_paths() {
    // The bi-level operator only consumes per-group maxima (bit-identical
    // across dispatches — max folds are order-insensitive) and the clamp
    // kernel (elementwise) — so scalar vs dispatched is exact, not ≤1e-6.
    let mut rng = Rng::new(0xFC02);
    for &(g, l) in &SHAPES {
        let data = adversarial_matrix(&mut rng, g, l);
        let norm = with_dispatch(Dispatch::Scalar, || norm_l1inf(GroupedView::new(&data, g, l)));
        if norm <= 1e-9 {
            continue;
        }
        for c in [0.2 * norm, 0.7 * norm] {
            let mut scalar = data.clone();
            let si = with_dispatch(Dispatch::Scalar, || project_bilevel(&mut scalar, g, l, c));
            let mut dispatched = data.clone();
            let di = project_bilevel(&mut dispatched, g, l, c);
            assert_eq!(si.tau.to_bits(), di.tau.to_bits(), "{g}x{l} c={c}");
            assert_eq!(scalar, dispatched, "{g}x{l} c={c}");
            assert_eq!(si.zero_groups, di.zero_groups);
            assert_eq!(si.radius_after.to_bits(), di.radius_after.to_bits());
        }
    }
}

#[test]
fn column_view_matches_transpose_bitwise_under_every_dispatch() {
    let mut rng = Rng::new(0xFC03);
    let (rows, cols) = (19, 11); // rows off the lane width
    let data = adversarial_matrix(&mut rng, rows, cols);
    let mut transposed_base = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            transposed_base[c * rows + r] = data[r * cols + c];
        }
    }
    for d in runnable_dispatches() {
        for algo in [Algorithm::InverseOrder, Algorithm::Newton, Algorithm::Bisection] {
            for c in [0.5, 2.0] {
                with_dispatch(d, || {
                    let mut transposed = transposed_base.clone();
                    let ti = project_l1inf(&mut transposed, cols, rows, c, algo);
                    let mut strided = data.clone();
                    let mut solver = new_solver(algo);
                    let si = project_with(
                        &mut *solver,
                        &mut GroupedViewMut::columns(&mut strided, rows, cols),
                        c,
                        None,
                    );
                    assert_eq!(
                        ti.theta.to_bits(),
                        si.theta.to_bits(),
                        "{d:?} {} c={c}",
                        algo.name()
                    );
                    for r in 0..rows {
                        for cc in 0..cols {
                            assert_eq!(
                                strided[r * cols + cc].to_bits(),
                                transposed[cc * rows + r].to_bits(),
                                "{d:?} {} c={c} ({r},{cc})",
                                algo.name()
                            );
                        }
                    }
                });
            }
        }
    }
}

#[test]
fn grouped_norms_agree_between_scalar_and_dispatched_paths() {
    let mut rng = Rng::new(0xFC04);
    for &(g, l) in &SHAPES {
        let data = adversarial_matrix(&mut rng, g, l);
        let view = GroupedView::new(&data, g, l);
        let (n1s, nls, n2s) = with_dispatch(Dispatch::Scalar, || {
            (norm_l1inf(view), norm_linf1(view), norm_l12(view))
        });
        let (n1d, nld, n2d) = (norm_l1inf(view), norm_linf1(view), norm_l12(view));
        // ℓ₁,∞ is max-based ⇒ bit-exact across dispatches.
        assert_eq!(n1s.to_bits(), n1d.to_bits(), "{g}x{l} norm_l1inf");
        assert!((nls - nld).abs() <= 1e-6 * nls.max(1.0), "{g}x{l}: {nls} vs {nld}");
        assert!((n2s - n2d).abs() <= 1e-6 * n2s.max(1.0), "{g}x{l}: {n2s} vs {n2d}");
    }
}

#[test]
fn denormal_heavy_groups_stay_finite_and_agree() {
    // A matrix dominated by subnormals with one ordinary group: the lane
    // split must neither flush, reorder into NaN, nor disagree with the
    // sequential scalar path beyond the documented bound.
    let (g, l) = (6usize, 11usize);
    let mut data = vec![1.0e-41f32; g * l];
    for i in 0..l {
        data[i] = if i % 2 == 0 { 0.75 } else { -0.75 }; // group 0: ordinary + ties
    }
    data[2 * l] = -3.0e-43; // signed subnormal
    data[3 * l..4 * l].fill(0.0); // whole-zero group
    let norm = with_dispatch(Dispatch::Scalar, || norm_l1inf(GroupedView::new(&data, g, l)));
    assert!(norm.is_finite() && norm > 0.0);
    for algo in Algorithm::ALL {
        let c = 0.4 * norm;
        let mut scalar = data.clone();
        let si = with_dispatch(Dispatch::Scalar, || project_l1inf(&mut scalar, g, l, c, algo));
        let mut dispatched = data.clone();
        let di = project_l1inf(&mut dispatched, g, l, c, algo);
        assert!(si.theta.is_finite() && di.theta.is_finite(), "{}", algo.name());
        assert!(
            (si.theta - di.theta).abs() <= 1e-6 * si.theta.abs().max(1.0),
            "{}: {} vs {}",
            algo.name(),
            si.theta,
            di.theta
        );
        for (a, b) in scalar.iter().zip(&dispatched) {
            assert!(a.is_finite() && b.is_finite());
            assert!((a - b).abs() <= 1e-6, "{}", algo.name());
        }
    }
}
