//! The dual-norm prox (§2.3 Moreau identity), the masked projection
//! (§3.3 Eq. 20), and the ℓ₁/ℓ₁,₂ comparison projections as used by the
//! SAE framework.

use l1inf::projection::l1inf::{project_l1inf, Algorithm};
use l1inf::projection::linf1::prox_linf1;
use l1inf::projection::masked::{apply_mask, project_masked};
use l1inf::projection::{l1, l12, norm_l1, norm_l12, norm_l1inf, norm_linf1, GroupedView};
use l1inf::util::prop;
use l1inf::util::rng::Rng;

fn random_signed(rng: &mut Rng, g: usize, l: usize, scale: f32) -> Vec<f32> {
    let mut y = vec![0.0f32; g * l];
    for v in y.iter_mut() {
        *v = (rng.f32() - 0.5) * scale;
    }
    y
}

#[test]
fn moreau_identity_exact_decomposition() {
    prop::check(
        "Y = prox_{C‖·‖∞,1}(Y) + P_{B₁,∞^C}(Y)",
        200,
        0xA0,
        |rng: &mut Rng| {
            let (g, l) = (rng.range(1, 10), rng.range(1, 10));
            let y = random_signed(rng, g, l, 4.0);
            let c = rng.f64() * 3.0 + 0.01;
            (y, g, l, c)
        },
        |(y, g, l, c)| {
            let mut prox = y.clone();
            prox_linf1(&mut prox, *g, *l, *c, Algorithm::InverseOrder);
            let mut proj = y.clone();
            project_l1inf(&mut proj, *g, *l, *c, Algorithm::InverseOrder);
            for i in 0..y.len() {
                if (prox[i] + proj[i] - y[i]).abs() > 1e-5 {
                    return Err(format!("decomposition fails at {i}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prox_shrinks_dual_norm_to_theta() {
    // For infeasible Y the prox residual has ℓ∞,₁ norm exactly θ* (every
    // surviving group sheds θ mass, dead groups keep ≤ θ).
    let mut rng = Rng::new(1);
    let (g, l) = (20, 8);
    let y = random_signed(&mut rng, g, l, 2.0);
    let c = 0.25 * norm_l1inf(GroupedView::new(&y, g, l));
    let mut prox = y.clone();
    let info = prox_linf1(&mut prox, g, l, c, Algorithm::Newton);
    assert!(!info.projection.feasible);
    assert!(
        (norm_linf1(GroupedView::new(&prox, g, l)) - info.projection.theta).abs() < 1e-4,
        "‖prox‖∞,1 = {} vs θ = {}",
        norm_linf1(GroupedView::new(&prox, g, l)),
        info.projection.theta
    );
}

#[test]
fn prox_nonexpansive() {
    // ‖prox(a) − prox(b)‖_F ≤ ‖a − b‖_F (firm nonexpansiveness, sampled).
    let mut rng = Rng::new(2);
    let (g, l) = (6, 6);
    for _ in 0..50 {
        let a = random_signed(&mut rng, g, l, 3.0);
        let b: Vec<f32> = a.iter().map(|&v| v + (rng.f32() - 0.5) * 0.5).collect();
        let c = 0.8;
        let mut pa = a.clone();
        prox_linf1(&mut pa, g, l, c, Algorithm::Bisection);
        let mut pb = b.clone();
        prox_linf1(&mut pb, g, l, c, Algorithm::Bisection);
        let dp: f64 = pa.iter().zip(&pb).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        let d: f64 = a.iter().zip(&b).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        assert!(dp <= d + 1e-6, "prox expanded distance: {dp} > {d}");
    }
}

#[test]
fn masked_projection_support_and_value_invariants() {
    prop::check(
        "masked keeps projection support with original values",
        150,
        0xA1,
        |rng: &mut Rng| {
            let (g, l) = (rng.range(1, 10), rng.range(1, 10));
            let y = random_signed(rng, g, l, 3.0);
            let norm = norm_l1inf(GroupedView::new(&y, g, l));
            let c = (0.1 + 0.7 * rng.f64()) * norm.max(0.01);
            (y, g, l, c)
        },
        |(y, g, l, c)| {
            let mut masked = y.clone();
            let mi = project_masked(&mut masked, *g, *l, *c, Algorithm::InverseOrder);
            if mi.projection.feasible {
                return Ok(());
            }
            let mut proj = y.clone();
            project_l1inf(&mut proj, *g, *l, *c, Algorithm::InverseOrder);
            for i in 0..y.len() {
                let (sm, sp) = (masked[i] != 0.0, proj[i] != 0.0);
                if sm != sp {
                    return Err(format!("support mismatch at {i}"));
                }
                if sm && masked[i] != y[i] {
                    return Err(format!("masked altered surviving value at {i}"));
                }
                if mi.mask[i] != sm {
                    return Err(format!("mask vector inconsistent at {i}"));
                }
            }
            // Masked norm dominates the projected norm (values unbounded).
            if norm_l1inf(GroupedView::new(&masked, *g, *l)) + 1e-6 < norm_l1inf(GroupedView::new(&proj, *g, *l)) {
                return Err("masked norm smaller than projected norm".into());
            }
            Ok(())
        },
    );
}

#[test]
fn mask_freezing_is_idempotent_under_updates() {
    let mut rng = Rng::new(3);
    let y = random_signed(&mut rng, 8, 4, 2.0);
    let mut w = y.clone();
    let mi = project_masked(&mut w, 8, 4, 1.0, Algorithm::InverseOrder);
    // Simulate gradient noise + refreeze, twice.
    for _ in 0..2 {
        for v in w.iter_mut() {
            *v += 0.05;
        }
        apply_mask(&mut w, &mi.mask);
        for i in 0..w.len() {
            assert_eq!(w[i] != 0.0, mi.mask[i] && (true), "frozen support changed");
            if !mi.mask[i] {
                assert_eq!(w[i], 0.0);
            }
        }
    }
}

#[test]
fn l1_and_l12_land_on_their_spheres() {
    let mut rng = Rng::new(4);
    let (g, l) = (12, 7);
    let y = random_signed(&mut rng, g, l, 3.0);

    let mut a = y.clone();
    let eta1 = 0.3 * norm_l1(&a);
    l1::project_l1(&mut a, eta1);
    assert!((norm_l1(&a) - eta1).abs() < 1e-3);

    let mut b = y.clone();
    let eta2 = 0.3 * norm_l12(GroupedView::new(&b, g, l));
    l12::project_l12(&mut b, g, l, eta2);
    assert!((norm_l12(GroupedView::new(&b, g, l)) - eta2).abs() < 1e-3);
}

#[test]
fn three_norms_produce_increasingly_structured_sparsity() {
    // The paper's qualitative claim: at comparable constraint tightness,
    // ℓ₁ scatters zeros, ℓ₁,₂ and ℓ₁,∞ zero whole groups.
    let mut rng = Rng::new(5);
    let (g, l) = (100, 16);
    let y = random_signed(&mut rng, g, l, 2.0);
    let frac = 0.05;

    let mut a = y.clone();
    l1::project_l1(&mut a, frac * norm_l1(&y));
    let mut b = y.clone();
    l12::project_l12(&mut b, g, l, frac * norm_l12(GroupedView::new(&y, g, l)));
    let mut c = y.clone();
    project_l1inf(&mut c, g, l, frac * norm_l1inf(GroupedView::new(&y, g, l)), Algorithm::InverseOrder);

    let groups_zeroed = |x: &[f32]| l1inf::projection::group_sparsity_pct(GroupedView::new(x, g, l));
    let l1_groups = groups_zeroed(&a);
    let l12_groups = groups_zeroed(&b);
    let l1inf_groups = groups_zeroed(&c);
    assert!(
        l12_groups > l1_groups,
        "group-lasso should zero more groups than l1 ({l12_groups} vs {l1_groups})"
    );
    assert!(
        l1inf_groups > l1_groups,
        "l1inf should zero more groups than l1 ({l1inf_groups} vs {l1_groups})"
    );
}
