//! The workspace-based solver core, end to end:
//!
//! 1. **Degenerate-shape equivalence** — all six algorithms agree (≤ 1e-6)
//!    on the shapes that historically break ℓ₁,∞ solvers: `group_len = 1`
//!    (the ball degenerates to ℓ₁), `n_groups = 1` (single-group
//!    waterfilling), whole-zero groups mixed in, and tied magnitudes
//!    across groups.
//! 2. **Workspace reuse** — one solver projecting a *sequence* of
//!    different-shaped matrices must match fresh-solver results exactly
//!    (bit-for-bit), so stale scratch state can never leak between calls.
//! 3. **Strided column views** — projecting the columns of a row-major
//!    matrix through `GroupedViewMut::columns` equals the transpose →
//!    project → transpose-back reference, with no transpose copy.

mod common;

use l1inf::projection::grouped::{GroupedView, GroupedViewMut};
use l1inf::projection::l1inf::{
    new_solver, project_l1inf, project_with, solve_theta, Algorithm, Solver,
};
use l1inf::projection::norm_l1inf;
use l1inf::util::prop;
use l1inf::util::rng::Rng;

/// All six solvers agree with the shared naive oracle (`common::`) on θ
/// and entries.
fn all_solvers_agree(data: &[f32], g: usize, l: usize, c: f64) -> Result<(), String> {
    let norm = norm_l1inf(GroupedView::new(data, g, l));
    if norm <= c || c <= 0.0 {
        return Ok(());
    }
    let abs: Vec<f32> = data.iter().map(|v| v.abs()).collect();
    let (reference, gold_theta) = common::oracle_l1inf(data, g, l, c);
    let scale = gold_theta.abs().max(1.0);
    for algo in Algorithm::ALL {
        let st = solve_theta(&abs, g, l, c, algo);
        if (st.theta - gold_theta).abs() > 1e-6 * scale {
            return Err(format!(
                "{}: theta {} != oracle {} (g={g} l={l} c={c})",
                algo.name(),
                st.theta,
                gold_theta
            ));
        }
        let mut out = data.to_vec();
        project_l1inf(&mut out, g, l, c, algo);
        for i in 0..out.len() {
            if (out[i] - reference[i]).abs() > 1e-6 {
                return Err(format!(
                    "{}: element {i}: {} vs {} (g={g} l={l} c={c})",
                    algo.name(),
                    out[i],
                    reference[i]
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn degenerate_group_len_one_reduces_to_l1_ball() {
    prop::check(
        "six solvers agree on group_len = 1 (the ℓ₁ ball)",
        120,
        0xD1,
        |rng: &mut Rng| {
            let n = rng.range(1, 50);
            let mut data = vec![0.0f32; n];
            for v in data.iter_mut() {
                *v = if rng.chance(0.2) { 0.0 } else { (rng.f32() - 0.5) * 4.0 };
            }
            let c = rng.f64() * 1.2 * norm_l1inf(GroupedView::new(&data, n, 1)).max(0.1);
            (data, n, c)
        },
        |(data, n, c)| {
            all_solvers_agree(data, *n, 1, *c)?;
            // Cross-check against the dedicated ℓ₁ projection.
            let norm = norm_l1inf(GroupedView::new(data, *n, 1));
            if norm > *c && *c > 0.0 {
                let mut via_l1inf = data.clone();
                project_l1inf(&mut via_l1inf, *n, 1, *c, Algorithm::InverseOrder);
                let mut via_l1 = data.clone();
                l1inf::projection::l1::project_l1(&mut via_l1, *c);
                for i in 0..data.len() {
                    if (via_l1inf[i] - via_l1[i]).abs() > 1e-5 {
                        return Err(format!("l1 mismatch at {i}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn degenerate_single_group_waterfilling() {
    prop::check(
        "six solvers agree on n_groups = 1 (single-group waterfilling)",
        120,
        0xD2,
        |rng: &mut Rng| {
            let l = rng.range(1, 40);
            let mut data = vec![0.0f32; l];
            for v in data.iter_mut() {
                *v = if rng.chance(0.25) { 0.5 } else { (rng.f32() - 0.5) * 3.0 };
            }
            let c = rng.f64() * 1.2 * norm_l1inf(GroupedView::new(&data, 1, l)).max(0.1);
            (data, l, c)
        },
        |(data, l, c)| {
            all_solvers_agree(data, 1, *l, *c)?;
            // A single group is clipped so its max equals C exactly.
            let norm = norm_l1inf(GroupedView::new(data, 1, *l));
            if norm > *c && *c > 0.0 {
                let mut out = data.clone();
                let info = project_l1inf(&mut out, 1, *l, *c, Algorithm::InverseOrder);
                if (info.radius_after - c).abs() > 1e-5 * c.max(1.0) {
                    return Err(format!("single group not clipped to C: {}", info.radius_after));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn degenerate_zero_groups_mixed_in() {
    prop::check(
        "six solvers agree with whole-zero groups mixed in",
        120,
        0xD3,
        |rng: &mut Rng| {
            let g = rng.range(2, 10);
            let l = rng.range(1, 10);
            let mut data = vec![0.0f32; g * l];
            for grp in 0..g {
                if rng.chance(0.5) {
                    continue; // whole-zero group
                }
                for i in 0..l {
                    data[grp * l + i] = (rng.f32() - 0.5) * 2.0;
                }
            }
            let c = rng.f64() * 1.1 * norm_l1inf(GroupedView::new(&data, g, l)).max(0.05);
            (data, g, l, c)
        },
        |(data, g, l, c)| all_solvers_agree(data, *g, *l, *c),
    );
}

#[test]
fn degenerate_tied_magnitudes_across_groups() {
    prop::check(
        "six solvers agree under heavy cross-group ties",
        120,
        0xD4,
        |rng: &mut Rng| {
            let g = rng.range(2, 10);
            let l = rng.range(1, 10);
            // Every entry drawn from a 3-value set ⇒ breakpoints tie across
            // and within groups constantly.
            let vals = [0.25f32, 0.5, 1.0];
            let mut data = vec![0.0f32; g * l];
            for v in data.iter_mut() {
                let x = vals[rng.below(3)];
                *v = if rng.chance(0.5) { -x } else { x };
            }
            let c = rng.f64() * 1.1 * norm_l1inf(GroupedView::new(&data, g, l)).max(0.1);
            (data, g, l, c)
        },
        |(data, g, l, c)| all_solvers_agree(data, *g, *l, *c),
    );
}

#[test]
fn reused_solver_exactly_matches_fresh_across_shapes() {
    // One reused workspace per algorithm, driven through a shape-changing
    // request sequence (grow, shrink, degenerate); every projection must be
    // bit-identical to a fresh solver's. This is the no-stale-state gate.
    let mut rng = Rng::new(0xA11);
    let shapes: [(usize, usize); 6] = [(12, 7), (40, 3), (12, 7), (1, 9), (33, 1), (5, 5)];
    for algo in Algorithm::ALL {
        let mut solver = new_solver(algo);
        for (step, &(g, l)) in shapes.iter().enumerate() {
            let mut data = vec![0.0f32; g * l];
            for v in data.iter_mut() {
                *v = (rng.f32() - 0.5) * 3.0;
            }
            let norm = norm_l1inf(GroupedView::new(&data, g, l));
            for c in [0.2 * norm, 0.8 * norm, norm + 1.0] {
                if c <= 0.0 {
                    continue;
                }
                let mut fresh = data.clone();
                let fi = project_l1inf(&mut fresh, g, l, c, algo);
                let mut reused = data.clone();
                let ri = project_with(
                    &mut *solver,
                    &mut GroupedViewMut::new(&mut reused, g, l),
                    c,
                    None,
                );
                assert_eq!(
                    fresh,
                    reused,
                    "{} step {step} shape ({g},{l}) c={c}: reused workspace drifted",
                    algo.name()
                );
                assert_eq!(fi.theta.to_bits(), ri.theta.to_bits(), "{} step {step}", algo.name());
                assert_eq!(fi.zero_groups, ri.zero_groups);
                assert_eq!(fi.feasible, ri.feasible);
            }
        }
    }
}

#[test]
fn stale_hint_from_previous_shape_cannot_corrupt() {
    // Feed each solver the θ* it remembered from a *different* matrix and
    // shape. The hint contract says any hint is safe: results must match a
    // cold fresh solve to solver precision.
    let mut rng = Rng::new(0xA12);
    for algo in Algorithm::ALL {
        let mut solver = new_solver(algo);
        // Solve shape A to plant a last θ*.
        let mut a = vec![0.0f32; 30 * 6];
        for v in a.iter_mut() {
            *v = (rng.f32() - 0.5) * 5.0;
        }
        project_with(&mut *solver, &mut GroupedViewMut::new(&mut a, 30, 6), 1.0, None);
        let stale = solver.last_theta();
        assert!(stale.is_some(), "{}", algo.name());
        // Project shape B with the stale hint.
        let mut b = vec![0.0f32; 8 * 17];
        for v in b.iter_mut() {
            *v = (rng.f32() - 0.5) * 0.8;
        }
        let c = 0.4 * norm_l1inf(GroupedView::new(&b, 8, 17));
        let mut cold = b.clone();
        let ci = project_l1inf(&mut cold, 8, 17, c, algo);
        let mut hinted = b.clone();
        let hi = project_with(
            &mut *solver,
            &mut GroupedViewMut::new(&mut hinted, 8, 17),
            c,
            stale,
        );
        let scale = ci.theta.abs().max(1.0);
        assert!(
            (hi.theta - ci.theta).abs() <= 1e-6 * scale,
            "{}: stale hint changed theta: {} vs {}",
            algo.name(),
            hi.theta,
            ci.theta
        );
        for i in 0..cold.len() {
            assert!(
                (hinted[i] - cold[i]).abs() <= 1e-6,
                "{}: stale hint corrupted entry {i}",
                algo.name()
            );
        }
    }
}

#[test]
fn column_view_matches_transposed_reference() {
    let mut rng = Rng::new(0xC01);
    let (rows, cols) = (19, 11);
    let mut data = vec![0.0f32; rows * cols];
    for v in data.iter_mut() {
        *v = (rng.f32() - 0.5) * 2.0;
    }
    for algo in Algorithm::ALL {
        for c in [0.5, 2.0, 100.0] {
            // Reference: explicit transpose → contiguous projection → back.
            let mut transposed = vec![0.0f32; rows * cols];
            for r in 0..rows {
                for cc in 0..cols {
                    transposed[cc * rows + r] = data[r * cols + cc];
                }
            }
            let ti = project_l1inf(&mut transposed, cols, rows, c, algo);
            let mut reference = vec![0.0f32; rows * cols];
            for r in 0..rows {
                for cc in 0..cols {
                    reference[r * cols + cc] = transposed[cc * rows + r];
                }
            }
            // Strided path: project the columns in place, no copies.
            let mut strided = data.clone();
            let mut solver = new_solver(algo);
            let si = project_with(
                &mut *solver,
                &mut GroupedViewMut::columns(&mut strided, rows, cols),
                c,
                None,
            );
            assert_eq!(ti.theta.to_bits(), si.theta.to_bits(), "{} c={c}", algo.name());
            assert_eq!(reference, strided, "{} c={c}", algo.name());
            assert_eq!(ti.zero_groups, si.zero_groups);
            assert_eq!(ti.feasible, si.feasible);
        }
    }
}

#[test]
fn column_view_norm_matches_contiguous_norm() {
    // Sanity on the view layer itself: per-group stats through the strided
    // view equal the transpose's contiguous stats bit for bit.
    let mut rng = Rng::new(0xC02);
    let (rows, cols) = (23, 9);
    let mut data = vec![0.0f32; rows * cols];
    for v in data.iter_mut() {
        *v = (rng.f32() - 0.5) * 3.0;
    }
    let mut transposed = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for cc in 0..cols {
            transposed[cc * rows + r] = data[r * cols + cc];
        }
    }
    let strided = GroupedView::columns(&data, rows, cols);
    let contiguous = GroupedView::new(&transposed, cols, rows);
    for g in 0..cols {
        let (ms, ss) = strided.group_abs_max_sum(g);
        let (mc, sc) = contiguous.group_abs_max_sum(g);
        assert_eq!(ms.to_bits(), mc.to_bits(), "group {g} max");
        assert_eq!(ss.to_bits(), sc.to_bits(), "group {g} sum");
    }
}
