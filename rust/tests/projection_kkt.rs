//! KKT certification: every solver's output satisfies the Lemma-1
//! optimality conditions, checked by the algorithm-independent verifier.

use l1inf::projection::kkt::{verify_l1inf, Tolerance};
use l1inf::projection::l1inf::{project_l1inf, Algorithm};
use l1inf::projection::{norm_l1inf, GroupedView};
use l1inf::util::prop;
use l1inf::util::rng::Rng;

#[test]
fn all_algorithms_produce_kkt_certified_projections() {
    prop::check(
        "KKT certificate holds for every solver",
        200,
        0x44,
        |rng: &mut Rng| {
            let (mut data, g, l) = prop::gen_projection_matrix(rng, 10, 12);
            for v in data.iter_mut() {
                if rng.chance(0.5) {
                    *v = -*v;
                }
            }
            let norm = norm_l1inf(GroupedView::new(&data, g, l));
            let c = (0.05 + 0.9 * rng.f64()) * norm.max(0.01);
            let algo = Algorithm::ALL[rng.below(Algorithm::ALL.len())];
            (data, g, l, c, algo)
        },
        |(y, g, l, c, algo)| {
            let mut x = y.clone();
            project_l1inf(&mut x, *g, *l, *c, *algo);
            verify_l1inf(y, &x, *g, *l, *c, Tolerance::default())
                .map(|_| ())
                .map_err(|e| format!("{}: {e}", algo.name()))
        },
    );
}

#[test]
fn certified_theta_matches_reported_theta() {
    let mut rng = Rng::new(0x99);
    for _ in 0..20 {
        let (g, l) = (rng.range(2, 20), rng.range(2, 20));
        let mut y = vec![0.0f32; g * l];
        for v in y.iter_mut() {
            *v = (rng.f32() - 0.5) * 3.0;
        }
        let norm = norm_l1inf(GroupedView::new(&y, g, l));
        let c = 0.4 * norm;
        if c <= 0.0 {
            continue;
        }
        let mut x = y.clone();
        let info = project_l1inf(&mut x, g, l, c, Algorithm::InverseOrder);
        let certified = verify_l1inf(&y, &x, g, l, c, Tolerance::default()).expect("KKT holds");
        assert!(
            (certified - info.theta).abs() < 1e-3 * info.theta.max(1.0),
            "certified θ {certified} vs reported {}",
            info.theta
        );
    }
}

#[test]
fn projection_is_distance_minimizing_vs_perturbations() {
    // The projection must be closer to Y than any feasible perturbation of
    // it — a direct (sampled) check of arg-min optimality.
    let mut rng = Rng::new(0x55);
    let (g, l) = (6, 8);
    let mut y = vec![0.0f32; g * l];
    for v in y.iter_mut() {
        *v = (rng.f32() - 0.5) * 4.0;
    }
    let c = 0.5 * norm_l1inf(GroupedView::new(&y, g, l));
    let mut x = y.clone();
    project_l1inf(&mut x, g, l, c, Algorithm::Bisection);
    let dist =
        |a: &[f32]| -> f64 { a.iter().zip(y.iter()).map(|(p, q)| ((p - q) as f64).powi(2)).sum() };
    let d_star = dist(&x);
    for _ in 0..200 {
        // random feasible candidate: perturb x then re-project to the ball
        let mut cand: Vec<f32> = x.iter().map(|&v| v + (rng.f32() - 0.5) * 0.2).collect();
        project_l1inf(&mut cand, g, l, c, Algorithm::Bisection);
        assert!(
            dist(&cand) + 1e-6 >= d_star,
            "found feasible point closer than the projection"
        );
    }
}

#[test]
fn verifier_rejects_tampered_outputs() {
    let mut rng = Rng::new(0x66);
    let (g, l) = (5, 6);
    let mut y = vec![0.0f32; g * l];
    for v in y.iter_mut() {
        *v = rng.f32() * 2.0;
    }
    let c = 0.3 * norm_l1inf(GroupedView::new(&y, g, l));
    let mut x = y.clone();
    project_l1inf(&mut x, g, l, c, Algorithm::InverseOrder);
    // sanity: untouched passes
    assert!(verify_l1inf(&y, &x, g, l, c, Tolerance::default()).is_ok());
    // tamper one surviving entry
    let idx = x.iter().position(|&v| v > 1e-3).unwrap();
    let mut bad = x.clone();
    bad[idx] *= 0.5;
    assert!(verify_l1inf(&y, &bad, g, l, c, Tolerance::default()).is_err());
    // revive a zeroed entry
    if let Some(zidx) = x.iter().position(|&v| v == 0.0) {
        let mut bad = x;
        bad[zidx] = 0.3;
        assert!(verify_l1inf(&y, &bad, g, l, c, Tolerance::default()).is_err());
    }
}
