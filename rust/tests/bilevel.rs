//! The bi-level / multi-level subsystem end to end: feasibility and
//! idempotence of the operator, the plain-ℓ₁ reduction (bit-exact), the
//! 2-level tree vs the serial operator, the `BatchProjector` routing, and
//! the TCP protocol's `"mode":"bilevel"` round-trip.

mod common;

use common::random_signed;
use l1inf::config::serve::ServeConfig;
use l1inf::projection::bilevel::{
    project_bilevel, project_bilevel_hinted, project_bilevel_tree, BilevelSolver, TreeBilevel,
};
use l1inf::projection::grouped::{GroupedView, GroupedViewMut};
use l1inf::projection::l1::project_l1;
use l1inf::projection::l1inf::{project_l1inf, Algorithm};
use l1inf::projection::norm_l1inf;
use l1inf::serve::server::Server;
use l1inf::util::json;
use l1inf::util::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Random and adversarial matrices in the style of the `Algorithm`
/// equivalence tests: `(data, n_groups, group_len, radius)` cases.
fn test_cases() -> Vec<(Vec<f32>, usize, usize, f64)> {
    let mut rng = Rng::new(0xB1CA5E);
    let mut cases = Vec::new();
    for (g, l) in [(37, 11), (64, 8), (9, 33)] {
        let data = random_signed(&mut rng, g * l, 3.0);
        let norm = norm_l1inf(GroupedView::new(&data, g, l));
        for frac in [0.05, 0.4, 0.9] {
            cases.push((data.clone(), g, l, frac * norm));
        }
    }
    // All-equal entries: every maxima ties with every other.
    cases.push((vec![0.5f32; 24 * 6], 24, 6, 1.3));
    // A single group.
    cases.push((vec![3.0f32, -2.0, 1.0, 0.5, -0.25, 0.0], 1, 6, 1.5));
    // Groups of length one (the operator degenerates to the ℓ₁ ball).
    cases.push(((0..40).map(|i| (i as f32 * 0.37).sin()).collect(), 40, 1, 2.0));
    // Already feasible: must be the identity.
    cases.push((vec![0.01f32; 16 * 4], 16, 4, 100.0));
    // Mostly-zero groups with a couple of heavies.
    let mut sparse = vec![0.0f32; 50 * 5];
    sparse[0] = 4.0;
    sparse[5] = -3.0;
    sparse[127] = 2.0;
    cases.push((sparse, 50, 5, 1.0));
    cases
}

#[test]
fn bilevel_is_feasible_and_idempotent() {
    for (data, g, l, c) in test_cases() {
        let mut once = data.clone();
        let info = project_bilevel(&mut once, g, l, c);
        let norm = norm_l1inf(GroupedView::new(&once, g, l));
        assert!(
            norm <= c * (1.0 + 1e-6) + 1e-9,
            "{g}x{l} C={c}: infeasible result ‖X‖₁,∞ = {norm}"
        );
        assert!(
            (norm - info.radius_after).abs() <= 1e-9 * norm.max(1.0),
            "{g}x{l} C={c}: reported radius_after drifted"
        );
        // Idempotence: projecting the projection is a no-op ≤ 1e-6.
        let mut twice = once.clone();
        let info2 = project_bilevel(&mut twice, g, l, c);
        for (a, b) in twice.iter().zip(&once) {
            assert!((a - b).abs() <= 1e-6, "{g}x{l} C={c}: not idempotent");
        }
        assert!(
            info2.feasible || info2.tau <= 1e-6 * c.max(1.0),
            "{g}x{l} C={c}: second pass re-projected (tau = {})",
            info2.tau
        );
        // Signs and magnitudes never grow.
        for (a, b) in once.iter().zip(&data) {
            assert!(a.abs() <= b.abs() + 1e-7);
            assert!(*a == 0.0 || a.signum() == b.signum());
        }
    }
}

#[test]
fn reduces_to_plain_l1_bitwise_when_every_group_has_one_nonzero() {
    // One nonzero per group ⇒ the ℓ₁,∞ geometry degenerates to the ℓ₁ ball
    // and the bi-level operator must agree with `project_l1` *bit-exactly*:
    // the maxima vector enumerates exactly the nonzeros, so the level-1
    // Condat solve sees the same values in the same order as the flat ℓ₁
    // projection (magnitudes ≥ 0.6 > C keep Condat's running threshold
    // positive, so the interleaved zeros never enter its active set), and
    // the clamp writes the identical `(|y| − τ)₊` floats.
    let mut rng = Rng::new(0x11B1);
    for (g, l) in [(50, 7), (200, 3), (12, 1)] {
        let mut data = vec![0.0f32; g * l];
        for grp in 0..g {
            // Group 0 keeps its nonzero at element 0 so both scans start
            // from the same first value; other groups place it anywhere.
            let pos = if grp == 0 { 0 } else { rng.below(l) };
            let mag = 0.6f32 + 1.4 * rng.f32();
            let sign: f32 = if rng.chance(0.5) { -1.0 } else { 1.0 };
            data[grp * l + pos] = sign * mag;
        }
        let c = 0.5;
        let mut bi = data.clone();
        let bi_info = project_bilevel(&mut bi, g, l, c);
        let mut l1 = data.clone();
        let l1_info = project_l1(&mut l1, c);
        assert_eq!(
            bi_info.tau.to_bits(),
            l1_info.tau.to_bits(),
            "{g}x{l}: bi-level τ must equal the ℓ₁ soft-threshold bit-exactly"
        );
        assert_eq!(bi, l1, "{g}x{l}: projected entries must match bit-exactly");
        // And the exact ℓ₁,∞ projection agrees up to solver precision.
        let mut exact = data.clone();
        project_l1inf(&mut exact, g, l, c, Algorithm::Bisection);
        for (a, b) in bi.iter().zip(&exact) {
            assert!((a - b).abs() <= 1e-5, "{g}x{l}: bi-level vs exact projection");
        }
    }
}

#[test]
fn tree_matches_serial_bilevel_everywhere() {
    for (data, g, l, c) in test_cases() {
        let mut serial = data.clone();
        let si = project_bilevel(&mut serial, g, l, c);
        for shards in [1usize, 2, 4, 7] {
            let mut par = data.clone();
            let pi = project_bilevel_tree(&mut par, g, l, c, shards);
            for i in 0..par.len() {
                assert!(
                    (par[i] - serial[i]).abs() <= 1e-6,
                    "{g}x{l} C={c} shards={shards}: entry {i}: {} vs {}",
                    par[i],
                    serial[i]
                );
            }
            let scale = si.tau.abs().max(1.0);
            assert!((pi.tau - si.tau).abs() <= 1e-6 * scale, "{g}x{l} C={c} shards={shards}");
            assert_eq!(pi.zero_groups, si.zero_groups, "{g}x{l} C={c} shards={shards}");
            assert_eq!(pi.feasible, si.feasible);
        }
    }
}

#[test]
fn warm_paths_match_cold_everywhere() {
    for (data, g, l, c) in test_cases() {
        let mut cold = data.clone();
        let ci = project_bilevel(&mut cold, g, l, c);
        let scale = ci.tau.abs().max(1.0);
        // External hints on either side of τ, plus hostile values.
        for hint in [ci.tau, ci.tau * 1.05, ci.tau * 0.5, ci.tau * 10.0, 0.0, f64::NAN] {
            let mut warm = data.clone();
            let wi = project_bilevel_hinted(&mut warm, g, l, c, Some(hint));
            assert!(
                (wi.tau - ci.tau).abs() <= 1e-6 * scale,
                "{g}x{l} C={c} hint={hint}: τ {} vs {}",
                wi.tau,
                ci.tau
            );
            for (a, b) in warm.iter().zip(&cold) {
                assert!((a - b).abs() <= 1e-6, "{g}x{l} C={c} hint={hint}");
            }
        }
        // Self-warm-start: a persistent workspace re-projecting the same
        // matrix must reproduce the cold result.
        let mut solver = BilevelSolver::new();
        for _ in 0..2 {
            let mut warm = data.clone();
            let wi = solver.project(&mut GroupedViewMut::new(&mut warm, g, l), c, None);
            assert!((wi.tau - ci.tau).abs() <= 1e-6 * scale, "{g}x{l} C={c} self-warm");
            for (a, b) in warm.iter().zip(&cold) {
                assert!((a - b).abs() <= 1e-6, "{g}x{l} C={c} self-warm");
            }
        }
        // Tree with a hint agrees too.
        let mut tree = TreeBilevel::new(3);
        let mut warm = data.clone();
        let wi = tree.project(&mut warm, g, l, c, Some(ci.tau * 1.05));
        assert!((wi.tau - ci.tau).abs() <= 1e-6 * scale, "{g}x{l} C={c} tree hint");
        for (a, b) in warm.iter().zip(&cold) {
            assert!((a - b).abs() <= 1e-6, "{g}x{l} C={c} tree hint");
        }
    }
}

#[test]
fn column_view_matches_explicit_transpose() {
    let mut rng = Rng::new(0xC01);
    let (rows, cols) = (9, 14);
    let data = random_signed(&mut rng, rows * cols, 2.0);
    // Transpose by hand, project contiguously, transpose back.
    let mut transposed = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            transposed[c * rows + r] = data[r * cols + c];
        }
    }
    let info_t = project_bilevel(&mut transposed, cols, rows, 0.8);
    // Project the columns in place through the strided view.
    let mut strided = data.clone();
    let info_s = BilevelSolver::new().project(
        &mut GroupedViewMut::columns(&mut strided, rows, cols),
        0.8,
        None,
    );
    assert_eq!(info_t.tau.to_bits(), info_s.tau.to_bits());
    for r in 0..rows {
        for c in 0..cols {
            assert_eq!(
                strided[r * cols + c].to_bits(),
                transposed[c * rows + r].to_bits(),
                "column view must be bit-identical to the transposed run"
            );
        }
    }
}

// ── TCP round-trip with mode = bilevel ──────────────────────────────────

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connecting to test server");
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn roundtrip(&mut self, line: &str) -> json::Json {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response '{resp}': {e}"))
    }
}

#[test]
fn server_round_trips_bilevel_mode() {
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), threads: 2, ..Default::default() };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());
    let mut client = Client::connect(addr);

    let (g, l, c) = (3usize, 4usize, 1.5f64);
    let y = vec![1.0f32, -0.5, 0.25, 0.0, 0.9, 0.8, -0.7, 0.1, 1.1, 0.2, 0.3, -0.4];
    let payload: Vec<String> = y.iter().map(|v| format!("{v}")).collect();
    let req = format!(
        r#"{{"id": 2, "op": "project", "key": "w1", "mode": "bilevel", "groups": {g}, "len": {l}, "radius": {c}, "data": [{}]}}"#,
        payload.join(",")
    );
    let resp = client.roundtrip(&req);
    assert_eq!(resp.get("ok"), Some(&json::Json::Bool(true)), "{resp}");
    assert_eq!(resp.get("mode").unwrap().as_str(), Some("bilevel"));
    assert_eq!(resp.get("warm"), Some(&json::Json::Bool(false)));

    // The echoed matrix matches the in-process operator and is feasible.
    let mut reference = y.clone();
    let ri = project_bilevel(&mut reference, g, l, c);
    let theta = resp.get("theta").unwrap().as_f64().unwrap();
    assert!((theta - ri.tau).abs() < 1e-9, "{theta} vs {}", ri.tau);
    let echoed = resp.get("data").unwrap().as_arr().unwrap();
    assert_eq!(echoed.len(), reference.len());
    let mut returned = Vec::with_capacity(echoed.len());
    for (a, b) in echoed.iter().zip(&reference) {
        let a = a.as_f64().unwrap();
        assert!((a - *b as f64).abs() < 1e-6);
        returned.push(a as f32);
    }
    let norm = norm_l1inf(GroupedView::new(&returned, g, l));
    assert!(norm <= c * (1.0 + 1e-6), "served matrix infeasible: {norm} > {c}");

    // Same key again: the bi-level τ cache namespace warm-starts without
    // changing the result.
    let req2 = req.replace(r#""id": 2"#, r#""id": 3"#);
    let resp2 = client.roundtrip(&req2);
    assert_eq!(resp2.get("warm"), Some(&json::Json::Bool(true)), "{resp2}");
    let theta2 = resp2.get("theta").unwrap().as_f64().unwrap();
    assert!((theta2 - ri.tau).abs() <= 1e-6 * ri.tau.max(1.0));

    // An exact-mode request under the same key stays cold: the τ cached by
    // the bi-level mode must not leak into the exact θ namespace.
    let req3 = req
        .replace(r#""id": 2"#, r#""id": 4"#)
        .replace(r#""mode": "bilevel", "#, "");
    let resp3 = client.roundtrip(&req3);
    assert_eq!(resp3.get("mode").unwrap().as_str(), Some("exact"));
    assert_eq!(resp3.get("warm"), Some(&json::Json::Bool(false)), "{resp3}");

    let bye = client.roundtrip(r#"{"id": 9, "op": "shutdown"}"#);
    assert_eq!(bye.get("shutting_down"), Some(&json::Json::Bool(true)));
    handle.join().expect("server thread").expect("server run");
}
