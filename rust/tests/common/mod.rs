//! Shared test support for the integration suites: deterministic seeded
//! matrix generators and a naive, self-contained reference oracle for the
//! ℓ₁,∞ / weighted-ℓ₁,∞ / bi-level operator families.
//!
//! This module dedupes the per-file generator copies that used to live in
//! `solver_workspace.rs`, `kernel_compat.rs`, `bilevel.rs` and
//! `serve_parallel.rs`, and is the single oracle the property-based
//! differential suite (`differential.rs`) checks every production solver
//! against.
//!
//! # The oracle
//!
//! The oracle is deliberately **independent of the production code paths**:
//! it never touches `projection::simplex`, the solver workspaces or the
//! dense kernel layer. It materializes each group's sorted magnitudes with
//! prefix sums (`O(nm log nm)`), enumerates *every* breakpoint of the
//! piecewise-linear root function, bisects the breakpoint list to the
//! piece containing the root, and solves that piece's linear equation
//! exactly in f64. Slow, simple, and exact to f64 round-off — which is
//! what a differential baseline should be.

#![allow(dead_code)] // shared across several test crates; each uses a subset

use l1inf::util::rng::Rng;

// ───────────────────────── generators ─────────────────────────

/// Uniform signed noise in `(-scale/2, scale/2)` (the shape every suite's
/// old local `random_signed` had).
pub fn random_signed(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    let mut y = vec![0.0f32; len];
    for v in y.iter_mut() {
        *v = (rng.f32() - 0.5) * scale;
    }
    y
}

/// Adversarial signed matrix: whole-zero groups, in-group zeros, heavy
/// cross-group ties at ±0.5, f32 denormals, and ordinary signed noise
/// (the `kernel_compat` generator, shared).
pub fn adversarial_matrix(rng: &mut Rng, g: usize, l: usize) -> Vec<f32> {
    let mut data = vec![0.0f32; g * l];
    for grp in 0..g {
        if rng.chance(0.15) {
            continue; // whole-zero group
        }
        for i in 0..l {
            data[grp * l + i] = match rng.below(10) {
                0 => 0.0,
                1 => 0.5,
                2 => -0.5,
                3 => 1.0e-41,  // subnormal
                4 => -2.5e-42, // subnormal
                _ => (rng.f32() - 0.5) * 3.0,
            };
        }
    }
    data
}

/// Structured matrix families the differential suite cycles through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatrixKind {
    /// Dense uniform signed noise.
    Dense,
    /// Mostly zeros with a few heavy entries.
    Sparse,
    /// Entries drawn from a tiny value set ⇒ breakpoints tie constantly.
    AdversarialTies,
    /// Subnormal-dominated groups with one ordinary group.
    Denormals,
    /// Random whole-zero groups mixed into signed noise.
    ZeroGroups,
}

pub const MATRIX_KINDS: [MatrixKind; 5] = [
    MatrixKind::Dense,
    MatrixKind::Sparse,
    MatrixKind::AdversarialTies,
    MatrixKind::Denormals,
    MatrixKind::ZeroGroups,
];

/// Deterministic matrix of the given structure.
pub fn matrix_of_kind(rng: &mut Rng, g: usize, l: usize, kind: MatrixKind) -> Vec<f32> {
    let mut data = vec![0.0f32; g * l];
    match kind {
        MatrixKind::Dense => {
            for v in data.iter_mut() {
                *v = (rng.f32() - 0.5) * 3.0;
            }
        }
        MatrixKind::Sparse => {
            for v in data.iter_mut() {
                if rng.chance(0.12) {
                    *v = (rng.f32() - 0.5) * 6.0;
                }
            }
        }
        MatrixKind::AdversarialTies => {
            let vals = [0.25f32, 0.5, 1.0];
            for v in data.iter_mut() {
                let x = vals[rng.below(3)];
                *v = if rng.chance(0.5) { -x } else { x };
            }
        }
        MatrixKind::Denormals => {
            for v in data.iter_mut() {
                *v = if rng.chance(0.5) { 1.0e-41 } else { -2.5e-42 };
            }
            // One ordinary group so the matrix has macroscopic mass.
            for i in 0..l {
                data[i] = (rng.f32() - 0.5) * 2.0;
            }
        }
        MatrixKind::ZeroGroups => {
            for grp in 0..g {
                if rng.chance(0.4) {
                    continue;
                }
                for i in 0..l {
                    data[grp * l + i] = (rng.f32() - 0.5) * 2.0;
                }
            }
        }
    }
    data
}

/// Random shape + structured content for one differential case.
pub fn gen_matrix(rng: &mut Rng, max_groups: usize, max_len: usize) -> (Vec<f32>, usize, usize) {
    let g = rng.range(1, max_groups + 1);
    let l = rng.range(1, max_len + 1);
    let kind = MATRIX_KINDS[rng.below(MATRIX_KINDS.len())];
    (matrix_of_kind(rng, g, l, kind), g, l)
}

/// Strictly positive per-group prices in `[0.2, 4.2)`.
pub fn positive_weights(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| 0.2 + rng.f32() * 4.0).collect()
}

// ─────────────── sparse-perturbation trajectories ───────────────

/// One step of a seeded sparse-perturbation trajectory: the rows changed
/// this step (ascending, unique) and the full pre-projection matrix after
/// the change.
pub struct TrajectoryStep {
    pub rows: Vec<u32>,
    pub y: Vec<f32>,
}

/// Simulated-SGD trajectory for the incremental delta solver: each step
/// rewrites a small random row subset with one of four moves — a small
/// nudge, a large rescale (support flips up), a zero-out (the group
/// dies), or a fresh-noise overwrite. The flip moves are the adversarial
/// part: they force the solver's support-tracking repair, not just the
/// water-level touch-up.
pub fn sparse_perturbation_trajectory(
    rng: &mut Rng,
    y0: &[f32],
    n_groups: usize,
    group_len: usize,
    steps: usize,
) -> Vec<TrajectoryStep> {
    let mut y = y0.to_vec();
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        let k = rng.range(1, n_groups.min(4) + 1);
        let mut rows: Vec<u32> =
            rng.sample_indices(n_groups, k).into_iter().map(|g| g as u32).collect();
        rows.sort_unstable();
        for &g in &rows {
            let row = &mut y[g as usize * group_len..(g as usize + 1) * group_len];
            match rng.below(4) {
                0 => row.iter_mut().for_each(|v| *v += (rng.f32() - 0.5) * 0.1),
                1 => row.iter_mut().for_each(|v| *v *= 8.0),
                2 => row.iter_mut().for_each(|v| *v = 0.0),
                _ => row.iter_mut().for_each(|v| *v = (rng.f32() - 0.5) * 3.0),
            }
        }
        out.push(TrajectoryStep { rows, y: y.clone() });
    }
    out
}

// ───────────────────────── the oracle ─────────────────────────

/// One group's sorted-magnitude representation.
struct OracleGroup {
    /// |y| sorted descending, f64.
    z: Vec<f64>,
    /// prefix[k] = Σ of the k largest magnitudes (prefix[0] = 0).
    prefix: Vec<f64>,
}

impl OracleGroup {
    fn build(group: &[f32]) -> OracleGroup {
        let mut z: Vec<f64> = group.iter().map(|&v| (v as f64).abs()).collect();
        z.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut prefix = Vec::with_capacity(z.len() + 1);
        prefix.push(0.0);
        let mut acc = 0.0;
        for &v in &z {
            acc += v;
            prefix.push(acc);
        }
        OracleGroup { z, prefix }
    }

    fn total(&self) -> f64 {
        *self.prefix.last().unwrap()
    }

    fn max(&self) -> f64 {
        self.z.first().copied().unwrap_or(0.0)
    }

    /// Water level μ removing exactly `theta` ℓ₁ mass (0 when the group
    /// dies, i.e. `theta ≥ total`): the unique μ ≥ 0 with
    /// `Σ max(z_i − μ, 0) = theta`.
    fn water_level(&self, theta: f64) -> f64 {
        if theta >= self.total() || self.z.is_empty() {
            return 0.0;
        }
        if theta <= 0.0 {
            return self.max();
        }
        for k in 1..=self.z.len() {
            let mu = (self.prefix[k] - theta) / k as f64;
            let next = if k < self.z.len() { self.z[k] } else { 0.0 };
            if mu >= next {
                return mu.max(0.0);
            }
        }
        0.0
    }

    /// Active count k at removed mass `theta` (entries strictly above the
    /// water level's piece; used for the exact piece solve).
    fn active_k(&self, theta: f64) -> usize {
        if theta >= self.total() {
            return 0;
        }
        for k in 1..=self.z.len() {
            let mu = (self.prefix[k] - theta) / k as f64;
            let next = if k < self.z.len() { self.z[k] } else { 0.0 };
            if mu >= next {
                return k;
            }
        }
        0
    }
}

fn build_groups(data: &[f32], n_groups: usize, group_len: usize) -> Vec<OracleGroup> {
    (0..n_groups)
        .map(|g| OracleGroup::build(&data[g * group_len..(g + 1) * group_len]))
        .collect()
}

/// Clip `data` at per-group levels `mu` (sign-preserving), f64 math.
fn clip(data: &[f32], n_groups: usize, group_len: usize, mu: &[f64]) -> Vec<f32> {
    let mut out = Vec::with_capacity(data.len());
    for g in 0..n_groups {
        for i in 0..group_len {
            let v = data[g * group_len + i] as f64;
            let m = mu[g].max(0.0);
            out.push((v.signum() * v.abs().min(m)) as f32);
        }
    }
    out
}

/// `Φ_w(λ) = Σ_g w_g·μ_g(λ·w_g)` on the oracle representation.
fn phi_w(groups: &[OracleGroup], weights: &[f64], lambda: f64) -> f64 {
    groups
        .iter()
        .zip(weights)
        .map(|(g, &w)| w * g.water_level(lambda * w))
        .sum()
}

/// Naive exact **weighted ℓ₁,∞** projection oracle. Returns the projected
/// matrix and the price λ (θ* when `weights ≡ 1`). `O(nm log nm)`:
/// per-group sorts, full breakpoint enumeration, bisection over the
/// breakpoint list, exact linear solve on the root's piece.
pub fn oracle_l1inf_weighted(
    data: &[f32],
    n_groups: usize,
    group_len: usize,
    weights: &[f32],
    c: f64,
) -> (Vec<f32>, f64) {
    assert_eq!(data.len(), n_groups * group_len);
    assert_eq!(weights.len(), n_groups);
    let w: Vec<f64> = weights.iter().map(|&x| x as f64).collect();
    let groups = build_groups(data, n_groups, group_len);

    let norm: f64 = groups.iter().zip(&w).map(|(g, &wg)| wg * g.max()).sum();
    if norm <= c {
        return (data.to_vec(), 0.0); // already feasible: identity
    }
    if c == 0.0 {
        let lambda = groups
            .iter()
            .zip(&w)
            .map(|(g, &wg)| g.total() / wg)
            .fold(0.0f64, f64::max);
        return (vec![0.0; data.len()], lambda);
    }

    // Every λ at which some group's active piece changes: λ_{g,k} =
    // (S_k − k·z_{k+1}) / w_g for k = 1..n (z_{n+1} := 0 ⇒ the death
    // point S_n / w_g).
    let mut bps: Vec<f64> = vec![0.0];
    for (g, wg) in groups.iter().zip(&w) {
        for k in 1..=g.z.len() {
            let next = if k < g.z.len() { g.z[k] } else { 0.0 };
            let theta = g.prefix[k] - k as f64 * next;
            if theta > 0.0 {
                bps.push(theta / wg);
            }
        }
    }
    bps.sort_by(|a, b| a.partial_cmp(b).unwrap());
    bps.dedup();

    // Φ_w is decreasing: bisect the breakpoint list for the first index
    // with Φ_w ≤ C; the root's piece is [bps[i−1], bps[i]].
    let (mut lo, mut hi) = (0usize, bps.len() - 1);
    // Invariant: Φ(bps[lo]) > C ≥ Φ(bps[hi]). Φ(0) = norm > C, and the
    // largest breakpoint is the last death point where Φ = 0 ≤ C.
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if phi_w(&groups, &w, bps[mid]) > c {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    // Exact linear solve on the piece, with per-group k read off at the
    // piece's midpoint: Σ_A w_g(S_k − λw_g)/k = C.
    let mid = 0.5 * (bps[lo] + bps[hi]);
    let mut t1 = 0.0f64; // Σ w_g·S_k/k
    let mut t2 = 0.0f64; // Σ w_g²/k
    for (g, &wg) in groups.iter().zip(&w) {
        let theta = mid * wg;
        let k = g.active_k(theta);
        if k == 0 {
            continue;
        }
        t1 += wg * g.prefix[k] / k as f64;
        t2 += wg * wg / k as f64;
    }
    let lambda = if t2 > 0.0 { (t1 - c) / t2 } else { mid };
    let mu: Vec<f64> =
        groups.iter().zip(&w).map(|(g, &wg)| g.water_level(lambda * wg)).collect();
    (clip(data, n_groups, group_len, &mu), lambda)
}

/// Naive exact **ℓ₁,∞** projection oracle (uniform prices). Returns the
/// projected matrix and θ*.
pub fn oracle_l1inf(data: &[f32], n_groups: usize, group_len: usize, c: f64) -> (Vec<f32>, f64) {
    let ones = vec![1.0f32; n_groups];
    oracle_l1inf_weighted(data, n_groups, group_len, &ones, c)
}

/// Naive **weighted bi-level** oracle: per-group maxima → weighted-simplex
/// projection of the maxima by sort-and-scan → clamp. Returns the clamped
/// matrix and the level-1 threshold τ.
pub fn oracle_bilevel_weighted(
    data: &[f32],
    n_groups: usize,
    group_len: usize,
    weights: &[f32],
    c: f64,
) -> (Vec<f32>, f64) {
    assert_eq!(data.len(), n_groups * group_len);
    assert_eq!(weights.len(), n_groups);
    let w: Vec<f64> = weights.iter().map(|&x| x as f64).collect();
    let maxes: Vec<f64> = (0..n_groups)
        .map(|g| {
            data[g * group_len..(g + 1) * group_len]
                .iter()
                .fold(0.0f32, |a, &v| a.max(v.abs())) as f64
        })
        .collect();
    let norm: f64 = maxes.iter().zip(&w).map(|(&v, &wg)| wg * v).sum();
    if norm <= c {
        return (data.to_vec(), 0.0);
    }
    if c == 0.0 {
        let tau = maxes.iter().zip(&w).map(|(&v, &wg)| v / wg).fold(0.0f64, f64::max);
        return (vec![0.0; data.len()], tau);
    }
    // Weighted simplex threshold by sorted scan over breakpoints v/w.
    let mut order: Vec<usize> = (0..n_groups).collect();
    order.sort_by(|&a, &b| (maxes[b] / w[b]).partial_cmp(&(maxes[a] / w[a])).unwrap());
    let mut cum_wv = 0.0f64;
    let mut cum_w2 = 0.0f64;
    let mut tau = 0.0f64;
    for &g in &order {
        cum_wv += w[g] * maxes[g];
        cum_w2 += w[g] * w[g];
        let t = (cum_wv - c) / cum_w2;
        if maxes[g] / w[g] > t {
            tau = t;
        } else {
            break;
        }
    }
    let tau = tau.max(0.0);
    let radii: Vec<f64> =
        maxes.iter().zip(&w).map(|(&v, &wg)| (v - tau * wg).max(0.0)).collect();
    (clip(data, n_groups, group_len, &radii), tau)
}

/// Naive **bi-level** oracle (uniform prices).
pub fn oracle_bilevel(data: &[f32], n_groups: usize, group_len: usize, c: f64) -> (Vec<f32>, f64) {
    let ones = vec![1.0f32; n_groups];
    oracle_bilevel_weighted(data, n_groups, group_len, &ones, c)
}

// ─────────────────── norms (oracle-side, f64) ───────────────────

/// Unweighted ℓ₁,∞ norm computed independently of the production kernels.
pub fn oracle_norm_l1inf(data: &[f32], n_groups: usize, group_len: usize) -> f64 {
    let ones = vec![1.0f32; n_groups];
    oracle_norm_l1inf_weighted(data, n_groups, group_len, &ones)
}

/// Weighted ℓ₁,∞ norm computed independently of the production kernels.
pub fn oracle_norm_l1inf_weighted(
    data: &[f32],
    n_groups: usize,
    group_len: usize,
    weights: &[f32],
) -> f64 {
    (0..n_groups)
        .map(|g| {
            let mx = data[g * group_len..(g + 1) * group_len]
                .iter()
                .fold(0.0f32, |a, &v| a.max(v.abs())) as f64;
            weights[g] as f64 * mx
        })
        .sum()
}
