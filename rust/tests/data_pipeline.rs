//! Data-substrate integration: generators → log-transform → split →
//! standardize → batches, end to end, at the paper's dimensions.

use l1inf::coordinator::{dataset_for, TRAIN_FRAC};
use l1inf::data::loader::{log_transform, stratified_split};
use l1inf::data::lung::{make_lung, LungSpec};
use l1inf::data::synthetic::{make_classification, SyntheticSpec};

#[test]
fn synthetic_paper_dimensions() {
    // Paper §6.1: n=1000, d=10000, 64 informative. (Full size — this is the
    // actual experiment input, generated in ~1s.)
    let ds = make_classification(&SyntheticSpec::default(), 0);
    ds.validate().unwrap();
    assert_eq!((ds.n, ds.d, ds.k), (1000, 10_000, 2));
    assert_eq!(ds.informative.len(), 64);
    let counts = ds.class_counts();
    assert!(counts.iter().all(|&c| c >= 450), "balanced-ish: {counts:?}");
}

#[test]
fn lung_paper_dimensions() {
    // Paper §6.2: 469 NSCLC + 536 controls × 2944 features.
    let ds = make_lung(&LungSpec::default(), 0);
    ds.validate().unwrap();
    assert_eq!((ds.n, ds.d), (1005, 2944));
    assert_eq!(ds.class_counts(), vec![536, 469]);
    assert_eq!(ds.informative.len(), 40);
}

#[test]
fn full_pipeline_lung() {
    let mut ds = make_lung(
        &LungSpec { n_cases: 60, n_controls: 70, d: 300, informative: 10, ..Default::default() },
        1,
    );
    log_transform(&mut ds);
    let sp = stratified_split(&ds, TRAIN_FRAC, 1);
    assert_eq!(sp.n_train + sp.n_test, 130);
    // standardized features are finite and O(1)
    assert!(sp.x_train.iter().all(|v| v.is_finite() && v.abs() < 30.0));
    // batches reconstruct rows exactly
    let order: Vec<usize> = (0..sp.n_train).collect();
    let (x, y) = sp.train_batch(&order, 0, 10);
    assert_eq!(x.shape(), &[10, 300]);
    assert_eq!(y.as_i32().unwrap().len(), 10);
    assert_eq!(x.as_f32().unwrap()[..300], sp.x_train[..300]);
}

#[test]
fn factory_matches_model_configs() {
    // The datasets must be at least as large as the AOT epoch windows.
    for (model, d, window) in [("tiny", 24, 64), ("synth_small", 2000, 800)] {
        let ds = dataset_for(model, 0).unwrap();
        assert_eq!(ds.d, d, "{model}");
        let sp = stratified_split(&ds, TRAIN_FRAC, 0);
        assert!(sp.n_train >= window, "{model}: {} < {window}", sp.n_train);
    }
}

#[test]
fn generators_vary_with_seed_but_not_within() {
    let a = dataset_for("tiny", 0).unwrap();
    let b = dataset_for("tiny", 0).unwrap();
    let c = dataset_for("tiny", 1).unwrap();
    assert_eq!(a.x, b.x);
    assert_ne!(a.x, c.x);
}

#[test]
fn informative_features_recoverable_by_univariate_screen() {
    // A simple t-statistic screen must rank planted features highly —
    // the signal the SAE is expected to find.
    let ds = make_classification(
        &SyntheticSpec { n: 400, d: 500, informative: 16, ..Default::default() },
        7,
    );
    let mut scores: Vec<(f64, usize)> = (0..ds.d)
        .map(|j| {
            let (mut s0, mut s1, mut n0, mut n1) = (0.0f64, 0.0f64, 0usize, 0usize);
            for i in 0..ds.n {
                let v = ds.row(i)[j] as f64;
                if ds.y[i] == 0 {
                    s0 += v;
                    n0 += 1;
                } else {
                    s1 += v;
                    n1 += 1;
                }
            }
            ((s0 / n0 as f64 - s1 / n1 as f64).abs(), j)
        })
        .collect();
    scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let top: std::collections::HashSet<usize> =
        scores[..32].iter().map(|&(_, j)| j).collect();
    let hits = ds.informative.iter().filter(|j| top.contains(j)).count();
    assert!(hits >= 12, "only {hits}/16 informative features in top-32 screen");
}
