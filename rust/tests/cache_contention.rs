//! Contention tests of the lock-free serve plane: colliding-slot traffic
//! against the packed-word [`ThetaCache`] (no torn θ reads, no
//! cross-family feeding, lossy-but-never-blended eviction — the
//! invariants documented in `docs/CONCURRENCY.md`) and admission-control
//! shedding over the real TCP surface (the typed `"overloaded"` error of
//! `docs/PROTOCOL.md`).

use l1inf::config::serve::ServeConfig;
use l1inf::serve::cache::{CacheKey, Family, ThetaCache};
use l1inf::serve::server::Server;
use l1inf::util::json::{self, Json};
use l1inf::util::rng::Rng;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Serializes the tests in this binary: the contention tests saturate
/// every core and the idle-CPU test measures whole-process CPU time, so
/// they must not overlap.
fn serial_lock() -> std::sync::MutexGuard<'static, ()> {
    static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());
    SERIAL.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// First pair of `k{i}` keys in one family whose hashes land on the same
/// table slot. [`ThetaCache::slot_of`] is deterministic, so this search
/// always finds the same pair (their 22-bit fingerprints differ — the
/// fingerprint is drawn from different hash bits than the slot).
fn colliding_pair(family: Family) -> (CacheKey, CacheKey) {
    let mut seen: HashMap<usize, usize> = HashMap::new();
    for i in 0..200_000usize {
        let key = CacheKey::new(family, format!("k{i}"));
        let slot = ThetaCache::slot_of(&key);
        if let Some(&j) = seen.get(&slot) {
            return (CacheKey::new(family, format!("k{j}")), key);
        }
        seen.insert(slot, i);
    }
    panic!("no colliding pair within 200k keys");
}

/// N threads hammer two keys that share one table slot. Every observed θ
/// must be (a) untorn — θ and its fingerprint travel in one atomic word,
/// so a read can never blend two writers — and (b) attributed to the key
/// it was recorded under: the keys' fingerprints differ, so the loser of
/// the slot reads as a miss, never as the winner's value.
#[test]
fn colliding_slots_never_tear_or_cross_feed() {
    let _serial = serial_lock();
    let (ka, kb) = colliding_pair(Family::Exact);
    assert_eq!(ThetaCache::slot_of(&ka), ThetaCache::slot_of(&kb));
    let cache = ThetaCache::new();
    const G: usize = 12;
    const L: usize = 6;
    // Disjoint integer θ ranges per key (integers are f32-exact, so a
    // round-tripped θ compares with ==).
    const A_BASE: u32 = 1000;
    const B_BASE: u32 = 5000;
    const ITERS: usize = 20_000;

    std::thread::scope(|s| {
        let cache = &cache;
        for (key, base) in [(&ka, A_BASE), (&kb, B_BASE), (&ka, A_BASE), (&kb, B_BASE)] {
            s.spawn(move || {
                for i in 0..ITERS {
                    cache.update(key, G, L, f64::from(base + (i as u32 % 100)));
                }
            });
        }
        for (key, base) in [(&ka, A_BASE), (&kb, B_BASE)] {
            s.spawn(move || {
                for _ in 0..ITERS {
                    if let Some(theta) = cache.entry(key, G, L) {
                        assert_eq!(theta.fract(), 0.0, "torn θ read for {key}: {theta}");
                        let t = theta as u32;
                        assert!(
                            (base..base + 100).contains(&t),
                            "θ {t} under {key} came from the other writer's range"
                        );
                    }
                }
            });
        }
    });

    // Lossy eviction, not corruption: exactly one collider owns the slot.
    let a_alive = cache.entry(&ka, G, L);
    let b_alive = cache.entry(&kb, G, L);
    assert!(
        a_alive.is_some() ^ b_alive.is_some(),
        "one last writer must own the slot: {a_alive:?} vs {b_alive:?}"
    );
    // Every valid update counted, overwritten or not.
    assert_eq!(cache.family_stats(Family::Exact).updates, (4 * ITERS) as u64);
}

/// Two *families* hammering one shared slot: the packed word carries a
/// 2-bit family tag, so a bilevel τ can never surface as an exact θ (or
/// vice versa) no matter how the writes interleave.
#[test]
fn families_never_cross_feed_even_on_a_shared_slot() {
    let _serial = serial_lock();
    let ka = CacheKey::new(Family::Exact, "alpha");
    let kb = (0..200_000usize)
        .map(|i| CacheKey::new(Family::Bilevel, format!("b{i}")))
        .find(|k| ThetaCache::slot_of(k) == ThetaCache::slot_of(&ka))
        .expect("no cross-family slot collision within 200k keys");
    let cache = ThetaCache::new();
    const G: usize = 10;
    const L: usize = 4;
    const A_BASE: u32 = 100;
    const B_BASE: u32 = 900;
    const ITERS: usize = 20_000;

    std::thread::scope(|s| {
        let cache = &cache;
        for (key, base) in [(&ka, A_BASE), (&kb, B_BASE)] {
            s.spawn(move || {
                for i in 0..ITERS {
                    cache.update(key, G, L, f64::from(base + (i as u32 % 100)));
                }
            });
        }
        for (key, base) in [(&ka, A_BASE), (&kb, B_BASE)] {
            s.spawn(move || {
                for _ in 0..ITERS {
                    if let Some(theta) = cache.entry(key, G, L) {
                        let t = theta as u32;
                        assert!(
                            theta.fract() == 0.0 && (base..base + 100).contains(&t),
                            "family {} read θ {theta} from the other family",
                            key.family.name()
                        );
                    }
                }
            });
        }
    });

    // The slot belongs to whichever family wrote last — never both.
    let a_alive = cache.entry(&ka, G, L);
    let b_alive = cache.entry(&kb, G, L);
    assert!(
        a_alive.is_some() ^ b_alive.is_some(),
        "families may evict each other but never co-own a slot: {a_alive:?} vs {b_alive:?}"
    );
}

/// Admission control over the real TCP surface: with a single worker and
/// `max_inflight = 1`, a huge in-flight request forces every concurrent
/// line into the typed `"overloaded"` rejection (served straight from the
/// event loop), the shed/accepted counters surface over `stats`, and the
/// pinned request itself still completes.
#[test]
fn overload_sheds_with_typed_error_and_counters() {
    let _serial = serial_lock();
    let sc = ServeConfig {
        addr: "127.0.0.1:0".into(),
        threads: 1,
        max_inflight: 1,
        ..ServeConfig::default()
    };
    let server = Server::bind(&sc).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());

    // Connection A: one very large projection (a ~13 MB request line) that
    // pins the single worker in parse → solve → render for a long window.
    let (groups, len) = (200_000usize, 8usize);
    let mut rng = Rng::new(0x0BE5E);
    let mut y = vec![0.0f32; groups * len];
    rng.fill_uniform_f32(&mut y);
    let data = y.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",");
    let big = format!(
        r#"{{"id":1,"op":"project","groups":{groups},"len":{len},"radius":0.5,"data":[{data}]}}"#
    );
    let mut a = TcpStream::connect(addr).unwrap();
    a.write_all(big.as_bytes()).unwrap();
    a.write_all(b"\n").unwrap();
    a.flush().unwrap();
    // `write_all` returning means the server ingested all but at most the
    // socket buffers; the pause lets the event loop read the tail and
    // dispatch the line, so the worker is provably busy before the probes.
    std::thread::sleep(Duration::from_millis(100));

    // Connection B: pings while the worker is pinned. The in-flight cap is
    // taken, so the event loop sheds them without touching the run queue.
    let b = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(b.try_clone().unwrap());
    let mut writer = b;
    let mut roundtrip = |line: String| -> Json {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        json::parse(&resp).unwrap()
    };
    let mut sheds = 0u64;
    let mut pongs = 0u64;
    for i in 0..200_000u64 {
        let id = 100 + i;
        let v = roundtrip(format!(r#"{{"id":{id},"op":"ping"}}"#));
        if v.get("overloaded") == Some(&Json::Bool(true)) {
            assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "shed must be ok:false: {v}");
            assert_eq!(
                v.get("id").and_then(Json::as_f64),
                Some(id as f64),
                "shed response must echo the probed id"
            );
            assert!(
                v.get("error").and_then(Json::as_str).unwrap().contains("overloaded"),
                "shed error text: {v}"
            );
            sheds += 1;
        } else if v.get("pong") == Some(&Json::Bool(true)) {
            pongs += 1;
            if sheds > 0 {
                break; // saw backpressure, then recovery — done probing
            }
        } else {
            panic!("unexpected response under overload: {v}");
        }
    }
    assert!(sheds >= 1, "no request was shed while the worker was pinned");
    assert!(pongs >= 1, "server never recovered to serve a ping");

    // The pinned request was accepted before the cap contended; its
    // response still arrives intact.
    let mut a_reader = BufReader::new(a);
    let mut resp = String::new();
    a_reader.read_line(&mut resp).unwrap();
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "pinned request must still succeed");

    // Both admission counters surface over the stats op.
    let v = roundtrip(r#"{"id":900,"op":"stats"}"#.to_string());
    let counters = v
        .get("metrics")
        .and_then(|m| m.get("counters"))
        .expect("stats carries the metrics counters");
    assert!(
        counters.get("serve.admission.shed").and_then(Json::as_f64).unwrap() >= sheds as f64,
        "shed counter must cover every typed rejection: {counters}"
    );
    assert!(
        counters.get("serve.admission.accepted").and_then(Json::as_f64).unwrap() >= 2.0,
        "accepted counter must cover the pinned request and the pong"
    );

    let v = roundtrip(r#"{"id":901,"op":"shutdown"}"#.to_string());
    assert_eq!(v.get("shutting_down"), Some(&Json::Bool(true)));
    handle.join().unwrap().unwrap();
}

/// Whole-process CPU time in clock ticks (utime + stime, usually 10ms
/// jiffies) from `/proc/self/stat`.
#[cfg(target_os = "linux")]
fn process_cpu_ticks() -> u64 {
    let stat = std::fs::read_to_string("/proc/self/stat").unwrap();
    // Fields after the last ')' start at field 3 (state); utime/stime are
    // fields 14/15 of the full line, i.e. tokens 11/12 of the tail. The
    // rfind guards against a ')' inside the comm field.
    let tail = &stat[stat.rfind(')').expect("malformed /proc/self/stat") + 1..];
    let fields: Vec<&str> = tail.split_whitespace().collect();
    let utime: u64 = fields[11].parse().unwrap();
    let stime: u64 = fields[12].parse().unwrap();
    utime + stime
}

/// The event loop must *park* when nothing is happening, not spin: an
/// idle server (listener bound, one quiet connection attached) may not
/// burn measurable CPU. Before the `poll(2)` wait the loop slept 300µs
/// per lap, so an idle server cost a few percent of a core forever;
/// parked in `poll` it costs a couple of heartbeat wakeups per second.
#[cfg(target_os = "linux")]
#[test]
fn idle_server_burns_no_cpu() {
    let _serial = serial_lock();
    let sc =
        ServeConfig { addr: "127.0.0.1:0".into(), threads: 2, ..ServeConfig::default() };
    let server = Server::bind(&sc).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());

    // A live, quiet connection keeps one per-connection fd in the poll
    // set: the idle cost must stay flat even with clients attached.
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut roundtrip = |line: &str| -> Json {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        json::parse(&resp).unwrap()
    };
    let v = roundtrip(r#"{"id":1,"op":"ping"}"#);
    assert_eq!(v.get("pong"), Some(&Json::Bool(true)));

    // The serial lock keeps the other tests in this binary out of the
    // measurement window; every other thread here blocks or sleeps.
    let before = process_cpu_ticks();
    std::thread::sleep(Duration::from_millis(1500));
    let spent = process_cpu_ticks() - before;
    // 5 ticks ≈ 50ms ≈ 3% of a core over the window. The parked loop
    // wakes ~3 times on the 500ms heartbeat and stays under 1 tick; the
    // old sleep tick spun ~5000 laps of accept/read/recv syscalls.
    assert!(
        spent <= 5,
        "idle server burned {spent} clock ticks in 1.5s — the event loop is spinning"
    );

    let v = roundtrip(r#"{"id":2,"op":"shutdown"}"#);
    assert_eq!(v.get("shutting_down"), Some(&Json::Bool(true)));
    handle.join().unwrap().unwrap();
}
