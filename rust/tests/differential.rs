//! Property-based **differential** suite: every production operator —
//! the six exact ℓ₁,∞ solvers, the bi-level operator and its sharded
//! tree (2/4 shards), the k-level multilevel generalization (k = 1..4,
//! serial and sharded), and the weighted family — is checked against
//! the naive, self-contained oracle in `common::` across ≥200 seeded
//! random shapes per family, plus the structural invariants every
//! projection must satisfy:
//!
//! - **oracle agreement**: θ/τ/λ within 1e-6·scale, entries within 1e-6;
//! - **feasibility**: the result lies in the (weighted) ball;
//! - **idempotence**: `P(P(X)) == P(X)` within 1e-6;
//! - **KKT certificates** on the exact and weighted families;
//! - **uniform-weights reduction**: the weighted operators with all-ones
//!   prices are *bit-identical* to their unweighted counterparts.
//!
//! Failures print the property name, seed and case index (see
//! `l1inf::util::prop`), so any counterexample is reproducible from the
//! log line alone.

mod common;

use l1inf::projection::bilevel::{project_bilevel, project_bilevel_tree};
use l1inf::projection::kkt::{self, Tolerance};
use l1inf::projection::l1inf::{project_l1inf, Algorithm, Delta, DeltaSolver};
use l1inf::projection::multilevel::{project_multilevel, MAX_DEPTH};
use l1inf::projection::weighted::{project_bilevel_weighted, project_l1inf_weighted};
use l1inf::serve::batch::ProjKind;
use l1inf::serve::cache::{CacheKey, Family, ThetaCache, REGISTRY};
use l1inf::util::prop;
use l1inf::util::rng::Rng;

/// Cases per family (the ISSUE floor is 200).
const CASES: usize = 210;

/// Shared case generator: structured random matrix + a radius spanning
/// deep-projection to near-feasible regimes (and occasionally infeasible
/// = identity).
fn gen_case(rng: &mut Rng) -> (Vec<f32>, usize, usize, f64) {
    let (data, g, l) = common::gen_matrix(rng, 14, 14);
    let norm = common::oracle_norm_l1inf(&data, g, l);
    let frac = [0.05, 0.2, 0.5, 0.8, 0.95, 1.2][rng.below(6)];
    let c = (frac * norm).max(1e-9);
    (data, g, l, c)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).fold(0.0, f64::max)
}

#[test]
fn every_exact_solver_matches_the_oracle() {
    prop::check(
        "six exact solvers vs naive oracle (θ, entries, feasibility, idempotence, KKT)",
        CASES,
        0xD1FF01,
        gen_case,
        |(data, g, l, c)| {
            let (g, l, c) = (*g, *l, *c);
            let (oracle_x, oracle_theta) = common::oracle_l1inf(data, g, l, c);
            let scale = oracle_theta.abs().max(1.0);
            for algo in Algorithm::ALL {
                let mut x = data.clone();
                let info = project_l1inf(&mut x, g, l, c, algo);
                if (info.theta - oracle_theta).abs() > 1e-6 * scale {
                    return Err(format!(
                        "{}: θ {} vs oracle {}",
                        algo.name(),
                        info.theta,
                        oracle_theta
                    ));
                }
                let diff = max_abs_diff(&x, &oracle_x);
                if diff > 1e-6 {
                    return Err(format!("{}: max |Δ| vs oracle = {diff:e}", algo.name()));
                }
                // Feasibility against the oracle's own norm.
                let after = common::oracle_norm_l1inf(&x, g, l);
                if after > c * (1.0 + 1e-6) + 1e-9 {
                    return Err(format!("{}: infeasible result {after} > {c}", algo.name()));
                }
                // Idempotence: re-projecting is a no-op.
                let mut twice = x.clone();
                project_l1inf(&mut twice, g, l, c, algo);
                let idem = max_abs_diff(&twice, &x);
                if idem > 1e-6 {
                    return Err(format!("{}: not idempotent, drift {idem:e}", algo.name()));
                }
            }
            // One KKT certificate per case (algorithm-independent; all six
            // just agreed with the oracle ≤1e-6).
            let mut x = data.clone();
            let info = project_l1inf(&mut x, g, l, c, Algorithm::Bisection);
            if !info.feasible {
                kkt::verify_l1inf(data, &x, g, l, c, Tolerance::default())
                    .map_err(|e| format!("KKT: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn incremental_delta_solver_tracks_the_oracle_over_trajectories() {
    prop::check(
        "incremental delta-projection vs oracle + cold re-solve over sparse-perturbation trajectories",
        CASES,
        0xD1FF05,
        |rng: &mut Rng| {
            let (y0, g, l) = common::gen_matrix(rng, 12, 12);
            let norm = common::oracle_norm_l1inf(&y0, g, l);
            let frac = [0.1, 0.3, 0.6, 0.9][rng.below(4)];
            let c = (frac * norm).max(1e-9);
            let steps = rng.range(2, 7);
            let traj = common::sparse_perturbation_trajectory(rng, &y0, g, l, steps);
            (y0, g, l, c, traj)
        },
        |(y0, g, l, c, traj)| {
            let (g, l, c) = (*g, *l, *c);
            let mut ds = DeltaSolver::new(c);
            let out0 = ds.begin(y0, g, l).map_err(|e| format!("begin: {e}"))?;
            let (oracle_x0, oracle_theta0) = common::oracle_l1inf(y0, g, l, c);
            if max_abs_diff(ds.x(), &oracle_x0) > 1e-6 {
                return Err(format!("begin: max |Δ| vs oracle {:e}", max_abs_diff(ds.x(), &oracle_x0)));
            }
            if (out0.info.theta - oracle_theta0).abs() > 1e-6 * oracle_theta0.abs().max(1.0) {
                return Err(format!("begin: θ {} vs oracle {}", out0.info.theta, oracle_theta0));
            }
            for (step, ts) in traj.iter().enumerate() {
                let out = ds
                    .solve_delta(&ts.y, &Delta::from_rows(ts.rows.iter().copied()))
                    .map_err(|e| format!("step {step}: {e}"))?;
                // Agreement with the naive oracle on the full new matrix…
                let (oracle_x, oracle_theta) = common::oracle_l1inf(&ts.y, g, l, c);
                let scale = oracle_theta.abs().max(1.0);
                if (out.info.theta - oracle_theta).abs() > 1e-6 * scale {
                    return Err(format!(
                        "step {step}: θ {} vs oracle {} (fallback: {})",
                        out.info.theta, oracle_theta, out.fallback
                    ));
                }
                let diff = max_abs_diff(ds.x(), &oracle_x);
                if diff > 1e-6 {
                    return Err(format!(
                        "step {step}: max |Δ| vs oracle {diff:e} (fallback: {})",
                        out.fallback
                    ));
                }
                // …and with a production cold re-solve of the same matrix.
                let mut cold = ts.y.clone();
                project_l1inf(&mut cold, g, l, c, Algorithm::Bisection);
                let cdiff = max_abs_diff(ds.x(), &cold);
                if cdiff > 1e-6 {
                    return Err(format!("step {step}: max |Δ| vs cold solve {cdiff:e}"));
                }
                // Feasibility of the maintained X.
                let after = common::oracle_norm_l1inf(ds.x(), g, l);
                if after > c * (1.0 + 1e-6) + 1e-9 {
                    return Err(format!("step {step}: infeasible result {after} > {c}"));
                }
                // A fallback must always carry its KKT certificate.
                if out.fallback && out.certified.is_none() {
                    return Err(format!("step {step}: uncertified fallback"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn hostile_or_stale_incremental_state_falls_back_certified() {
    let mut rng = Rng::new(0xD1FF06);
    let (g, l) = (12, 10);
    let y0 = common::random_signed(&mut rng, g * l, 3.0);
    let norm = common::oracle_norm_l1inf(&y0, g, l);
    let c = 0.25 * norm;
    let mut ds = DeltaSolver::new(c);
    ds.begin(&y0, g, l).unwrap();
    assert!(ds.theta() > 0.0, "case must start infeasible");

    // Hostile persisted state: rewrite EVERY row but declare only row 0 —
    // the audit/trust machinery must catch the lie and take the certified
    // cold fallback instead of trusting the stale structures.
    let y1: Vec<f32> = y0.iter().map(|v| v * 40.0).collect();
    let out = ds.solve_delta(&y1, &Delta::from_rows([0u32])).unwrap();
    assert!(out.fallback, "undeclared full rewrite must force the cold fallback");
    assert!(out.certified.is_some(), "fallback must be KKT-certified");
    let (oracle_x, _) = common::oracle_l1inf(&y1, g, l, c);
    assert!(max_abs_diff(ds.x(), &oracle_x) <= 1e-6);

    // Stale state across a shape change is a typed error, never a silent
    // cold solve of mismatched data.
    let err = ds.solve_delta(&y1[..(g - 1) * l], &Delta::from_rows([0u32])).unwrap_err();
    assert!(err.contains("shape"), "unexpected error: {err}");
    // The failed call must not have poisoned the persisted state.
    assert!(ds.is_ready());
    let out = ds.solve_delta(&y1, &Delta::from_rows([0u32])).unwrap();
    assert!(max_abs_diff(ds.x(), &oracle_x) <= 1e-6);
    assert!(!out.fallback, "an honest no-op delta needs no fallback");
}

#[test]
fn weighted_family_matches_the_oracle_and_reduces_bitwise() {
    prop::check(
        "weighted ℓ₁,∞ vs oracle + bit-exact uniform reduction + weighted KKT",
        CASES,
        0xD1FF02,
        |rng: &mut Rng| {
            let (data, g, l) = common::gen_matrix(rng, 14, 14);
            let w = common::positive_weights(rng, g);
            let norm = common::oracle_norm_l1inf_weighted(&data, g, l, &w);
            let frac = [0.05, 0.3, 0.6, 0.9, 1.2][rng.below(5)];
            let c = (frac * norm).max(1e-9);
            (data, g, l, w, c)
        },
        |(data, g, l, w, c)| {
            let (g, l, c) = (*g, *l, *c);
            // 1. Oracle agreement under random prices.
            let (oracle_x, oracle_lambda) = common::oracle_l1inf_weighted(data, g, l, w, c);
            let mut x = data.clone();
            let info = project_l1inf_weighted(&mut x, g, l, c, w);
            let scale = oracle_lambda.abs().max(1.0);
            if (info.theta - oracle_lambda).abs() > 1e-6 * scale {
                return Err(format!("λ {} vs oracle {}", info.theta, oracle_lambda));
            }
            let diff = max_abs_diff(&x, &oracle_x);
            if diff > 1e-6 {
                return Err(format!("max |Δ| vs oracle = {diff:e}"));
            }
            // 2. Feasibility + weighted KKT certificate.
            let after = common::oracle_norm_l1inf_weighted(&x, g, l, w);
            if after > c * (1.0 + 1e-6) + 1e-9 {
                return Err(format!("infeasible: {after} > {c}"));
            }
            if !info.feasible {
                kkt::verify_l1inf_weighted(data, &x, g, l, w, c, Tolerance::default())
                    .map_err(|e| format!("weighted KKT: {e}"))?;
            }
            // 3. Idempotence.
            let mut twice = x.clone();
            project_l1inf_weighted(&mut twice, g, l, c, w);
            let idem = max_abs_diff(&twice, &x);
            if idem > 1e-6 {
                return Err(format!("not idempotent, drift {idem:e}"));
            }
            // 4. Uniform prices reduce *bit-exactly* to the exact
            // bisection projection — the ISSUE acceptance criterion.
            let ones = vec![1.0f32; g];
            let mut weighted = data.clone();
            let wi = project_l1inf_weighted(&mut weighted, g, l, c, &ones);
            let mut exact = data.clone();
            let ei = project_l1inf(&mut exact, g, l, c, Algorithm::Bisection);
            if wi.theta.to_bits() != ei.theta.to_bits() {
                return Err(format!(
                    "uniform reduction: λ bits {} != θ bits {}",
                    wi.theta, ei.theta
                ));
            }
            for (i, (a, b)) in weighted.iter().zip(&exact).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("uniform reduction: entry {i}: {a} vs {b} (bits)"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn bilevel_and_tree_match_the_oracle() {
    prop::check(
        "bi-level + 2/4-shard tree vs naive oracle (τ, entries, feasibility, idempotence)",
        CASES,
        0xD1FF03,
        gen_case,
        |(data, g, l, c)| {
            let (g, l, c) = (*g, *l, *c);
            let (oracle_x, oracle_tau) = common::oracle_bilevel(data, g, l, c);
            let scale = oracle_tau.abs().max(1.0);
            let mut x = data.clone();
            let info = project_bilevel(&mut x, g, l, c);
            if (info.tau - oracle_tau).abs() > 1e-6 * scale {
                return Err(format!("τ {} vs oracle {}", info.tau, oracle_tau));
            }
            let diff = max_abs_diff(&x, &oracle_x);
            if diff > 1e-6 {
                return Err(format!("serial max |Δ| vs oracle = {diff:e}"));
            }
            let after = common::oracle_norm_l1inf(&x, g, l);
            if after > c * (1.0 + 1e-6) + 1e-9 {
                return Err(format!("infeasible: {after} > {c}"));
            }
            // Tree with 2 and 4 shards against the same oracle.
            for shards in [2usize, 4] {
                let mut t = data.clone();
                let ti = project_bilevel_tree(&mut t, g, l, c, shards);
                if (ti.tau - oracle_tau).abs() > 1e-6 * scale {
                    return Err(format!(
                        "tree x{shards}: τ {} vs oracle {}",
                        ti.tau, oracle_tau
                    ));
                }
                let tdiff = max_abs_diff(&t, &oracle_x);
                if tdiff > 1e-6 {
                    return Err(format!("tree x{shards}: max |Δ| vs oracle = {tdiff:e}"));
                }
            }
            // Idempotence of the serial operator.
            let mut twice = x.clone();
            project_bilevel(&mut twice, g, l, c);
            let idem = max_abs_diff(&twice, &x);
            if idem > 1e-6 {
                return Err(format!("not idempotent, drift {idem:e}"));
            }
            Ok(())
        },
    );
}

/// The k-level multilevel operator against the same naive oracle as the
/// bi-level family, at every depth k = 1..4 (plus `MAX_DEPTH`), serial
/// and sharded. The recursion only re-partitions group index ranges —
/// the per-group |max| fold, the root simplex solve and the clamp are
/// the shared bi-level kernels — so beyond oracle agreement every
/// (depth, threads) cell must be **bit-identical** to the serial
/// bi-level operator, and depth 2 with a matching shard count must be
/// bit-identical to the flat sharded tree.
#[test]
fn multilevel_matches_the_oracle_at_every_depth() {
    prop::check(
        "k-level multilevel (k=1..4 + max, serial/sharded) vs oracle + bit-identity to bi-level",
        CASES,
        0xD1FF07,
        gen_case,
        |(data, g, l, c)| {
            let (g, l, c) = (*g, *l, *c);
            let (oracle_x, oracle_tau) = common::oracle_bilevel(data, g, l, c);
            let scale = oracle_tau.abs().max(1.0);
            let mut reference = data.clone();
            let ri = project_bilevel(&mut reference, g, l, c);
            for depth in [1usize, 2, 3, 4, MAX_DEPTH] {
                for threads in [1usize, 3] {
                    let mut x = data.clone();
                    let info = project_multilevel(&mut x, g, l, c, depth, threads);
                    if (info.tau - oracle_tau).abs() > 1e-6 * scale {
                        return Err(format!(
                            "k={depth} x{threads}: τ {} vs oracle {}",
                            info.tau, oracle_tau
                        ));
                    }
                    let diff = max_abs_diff(&x, &oracle_x);
                    if diff > 1e-6 {
                        return Err(format!(
                            "k={depth} x{threads}: max |Δ| vs oracle = {diff:e}"
                        ));
                    }
                    if info.tau.to_bits() != ri.tau.to_bits() {
                        return Err(format!(
                            "k={depth} x{threads}: τ {} not bit-identical to bi-level {}",
                            info.tau, ri.tau
                        ));
                    }
                    for (i, (a, b)) in x.iter().zip(&reference).enumerate() {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!(
                                "k={depth} x{threads}: entry {i}: {a} vs bi-level {b} (bits)"
                            ));
                        }
                    }
                }
            }
            // Depth 2 with a matching shard count reduces to the flat
            // sharded tree, bitwise — the ISSUE acceptance criterion.
            for shards in [2usize, 4] {
                let mut t = data.clone();
                let ti = project_bilevel_tree(&mut t, g, l, c, shards);
                let mut m = data.clone();
                let mi = project_multilevel(&mut m, g, l, c, 2, shards);
                if mi.tau.to_bits() != ti.tau.to_bits() {
                    return Err(format!(
                        "k=2 x{shards}: τ {} != tree τ {} (bits)",
                        mi.tau, ti.tau
                    ));
                }
                for (i, (a, b)) in m.iter().zip(&t).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("k=2 x{shards}: entry {i}: {a} vs tree {b} (bits)"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The operator-family registry is the one table the config parser, the
/// serve router and the θ-cache namespaces hang off: every family must
/// round-trip through its config string, its serve mode (and every
/// alias), and own a cache namespace that never feeds a neighbor.
#[test]
fn registry_round_trips_every_family() {
    assert_eq!(Family::ALL.len(), REGISTRY.len());
    for spec in &REGISTRY {
        let kind: ProjKind = spec.mode.parse().unwrap();
        assert_eq!(kind.family(), spec.family, "mode '{}' routes to its family", spec.mode);
        assert_eq!(kind.name(), spec.mode, "serve mode name round-trips");
        for alias in spec.aliases {
            let kind: ProjKind = alias.parse().unwrap();
            assert_eq!(
                kind.family(),
                spec.family,
                "alias '{alias}' routes to family '{}'",
                spec.family.name()
            );
        }
        // The trainer-side config string names a real projection mode.
        assert!(
            l1inf::config::train::projection_mode(spec.config_name, 1.0).is_ok(),
            "config name '{}' must parse as a projection mode",
            spec.config_name
        );
    }

    // Namespace isolation: one client key, four families, one shared
    // cache. Pick a key whose four typed slots don't collide so lossy
    // eviction (a separate, tested property) can't mask cross-feeding.
    let client = (0..10_000)
        .map(|i| format!("ns{i}"))
        .find(|k| {
            let slots: std::collections::HashSet<usize> = Family::ALL
                .iter()
                .map(|f| ThetaCache::slot_of(&CacheKey::new(*f, k.clone())))
                .collect();
            slots.len() == Family::ALL.len()
        })
        .expect("some key maps the four families to distinct slots");
    let cache = ThetaCache::new();
    for (i, family) in Family::ALL.iter().enumerate() {
        cache.update(&CacheKey::new(*family, client.clone()), 4, 3, 10.0 + i as f64);
    }
    for (i, family) in Family::ALL.iter().enumerate() {
        assert_eq!(
            cache.entry(&CacheKey::new(*family, client.clone()), 4, 3),
            Some(10.0 + i as f64),
            "family '{}' must read back its own θ, never a neighbor's",
            family.name()
        );
    }
}

#[test]
fn weighted_bilevel_matches_the_oracle_and_reduces_bitwise() {
    prop::check(
        "weighted bi-level vs oracle + bit-exact uniform reduction",
        CASES,
        0xD1FF04,
        |rng: &mut Rng| {
            let (data, g, l) = common::gen_matrix(rng, 14, 14);
            let w = common::positive_weights(rng, g);
            let norm = common::oracle_norm_l1inf_weighted(&data, g, l, &w);
            let frac = [0.05, 0.3, 0.6, 0.9, 1.2][rng.below(5)];
            let c = (frac * norm).max(1e-9);
            (data, g, l, w, c)
        },
        |(data, g, l, w, c)| {
            let (g, l, c) = (*g, *l, *c);
            let (oracle_x, oracle_tau) = common::oracle_bilevel_weighted(data, g, l, w, c);
            let scale = oracle_tau.abs().max(1.0);
            let mut x = data.clone();
            let info = project_bilevel_weighted(&mut x, g, l, c, w);
            if (info.tau - oracle_tau).abs() > 1e-6 * scale {
                return Err(format!("τ {} vs oracle {}", info.tau, oracle_tau));
            }
            let diff = max_abs_diff(&x, &oracle_x);
            if diff > 1e-6 {
                return Err(format!("max |Δ| vs oracle = {diff:e}"));
            }
            let after = common::oracle_norm_l1inf_weighted(&x, g, l, w);
            if after > c * (1.0 + 1e-6) + 1e-9 {
                return Err(format!("infeasible: {after} > {c}"));
            }
            // Idempotence.
            let mut twice = x.clone();
            project_bilevel_weighted(&mut twice, g, l, c, w);
            let idem = max_abs_diff(&twice, &x);
            if idem > 1e-6 {
                return Err(format!("not idempotent, drift {idem:e}"));
            }
            // Bit-exact uniform reduction to the unweighted operator.
            let ones = vec![1.0f32; g];
            let mut weighted = data.clone();
            let wi = project_bilevel_weighted(&mut weighted, g, l, c, &ones);
            let mut plain = data.clone();
            let pi = project_bilevel(&mut plain, g, l, c);
            if wi.tau.to_bits() != pi.tau.to_bits() {
                return Err(format!(
                    "uniform reduction: τ bits {} != {}",
                    wi.tau, pi.tau
                ));
            }
            for (i, (a, b)) in weighted.iter().zip(&plain).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("uniform reduction: entry {i}: {a} vs {b} (bits)"));
                }
            }
            Ok(())
        },
    );
}
