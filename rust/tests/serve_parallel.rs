//! The serve subsystem end to end: the sharded [`BatchProjector`] must be
//! indistinguishable (≤ 1e-6 elementwise) from the serial reference for
//! every solver on random and adversarial inputs; warm-started solves must
//! return the cold θ*; and the TCP protocol must round-trip projections.

mod common;

use common::random_signed;
use l1inf::config::serve::ServeConfig;
use l1inf::projection::l1inf::{project_l1inf, project_l1inf_with_hint, Algorithm};
use l1inf::projection::{norm_l1inf, GroupedView};
use l1inf::serve::batch::{BatchProjector, ProjKind, ProjRequest};
use l1inf::serve::cache::ThetaCache;
use l1inf::serve::server::Server;
use l1inf::util::json;
use l1inf::util::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Parallel vs serial on one input, all thread counts worth exercising.
fn assert_parallel_matches_serial(data: &[f32], g: usize, l: usize, c: f64, algo: Algorithm) {
    let mut serial = data.to_vec();
    let si = project_l1inf(&mut serial, g, l, c, algo);
    for threads in [2usize, 4, 7] {
        // Threshold 0 forces the sharded path: these matrices are far below
        // the production serial-fallback cutoff.
        let pool = BatchProjector::with_min_parallel(threads, 0);
        let mut par = data.to_vec();
        let pi = pool.project_parallel(&mut par, g, l, c, algo, None);
        let scale = si.theta.abs().max(1.0);
        assert!(
            (pi.theta - si.theta).abs() <= 1e-6 * scale,
            "{} x{threads} g={g} l={l} c={c}: theta {} vs {}",
            algo.name(),
            pi.theta,
            si.theta
        );
        for i in 0..par.len() {
            assert!(
                (par[i] - serial[i]).abs() <= 1e-6,
                "{} x{threads} g={g} l={l} c={c}: entry {i}: {} vs {}",
                algo.name(),
                par[i],
                serial[i]
            );
        }
        assert_eq!(pi.zero_groups, si.zero_groups, "{} x{threads}", algo.name());
        assert_eq!(pi.feasible, si.feasible);
        assert!((pi.radius_before - si.radius_before).abs() <= 1e-6 * si.radius_before.max(1.0));
        assert!((pi.radius_after - si.radius_after).abs() <= 1e-5 * si.radius_after.max(1.0));
    }
}

#[test]
fn parallel_matches_serial_every_algorithm_random() {
    let mut rng = Rng::new(0xC0FFEE);
    for algo in Algorithm::ALL {
        for (g, l) in [(37, 11), (64, 8), (9, 33)] {
            let data = random_signed(&mut rng, g * l, 3.0);
            let norm = norm_l1inf(GroupedView::new(&data, g, l));
            for frac in [0.05, 0.4, 0.9] {
                assert_parallel_matches_serial(&data, g, l, frac * norm, algo);
            }
        }
    }
}

#[test]
fn parallel_matches_serial_adversarial() {
    for algo in Algorithm::ALL {
        // All-equal entries: every breakpoint ties with every other.
        let data = vec![0.5f32; 24 * 6];
        assert_parallel_matches_serial(&data, 24, 6, 1.3, algo);
        // A single group.
        let single = vec![3.0f32, -2.0, 1.0, 0.5, -0.25, 0.0];
        assert_parallel_matches_serial(&single, 1, 6, 1.5, algo);
        // Groups of length one (the matrix degenerates to an ℓ₁ ball).
        let thin: Vec<f32> = (0..40).map(|i| (i as f32 * 0.37).sin()).collect();
        assert_parallel_matches_serial(&thin, 40, 1, 2.0, algo);
        // Already feasible: the projection must be the identity.
        let feasible = vec![0.01f32; 16 * 4];
        assert_parallel_matches_serial(&feasible, 16, 4, 100.0, algo);
        // Mostly-zero groups with a couple of heavies.
        let mut sparse = vec![0.0f32; 50 * 5];
        sparse[0] = 4.0;
        sparse[5] = -3.0;
        sparse[127] = 2.0;
        assert_parallel_matches_serial(&sparse, 50, 5, 1.0, algo);
    }
}

#[test]
fn warm_start_returns_cold_theta_for_all_hinted_solvers() {
    let mut rng = Rng::new(0xFACE);
    let (g, l) = (80, 12);
    let data = random_signed(&mut rng, g * l, 2.0);
    for algo in Algorithm::ALL {
        let mut cold_m = data.clone();
        let cold = project_l1inf(&mut cold_m, g, l, 1.0, algo);
        let scale = cold.theta.abs().max(1.0);
        for factor in [1.0, 1.05, 0.8, 3.0] {
            let mut warm_m = data.clone();
            let warm =
                project_l1inf_with_hint(&mut warm_m, g, l, 1.0, algo, Some(cold.theta * factor));
            assert!(
                (warm.theta - cold.theta).abs() <= 1e-6 * scale,
                "{} hint x{factor}: {} vs {}",
                algo.name(),
                warm.theta,
                cold.theta
            );
            for i in 0..warm_m.len() {
                assert!(
                    (warm_m[i] - cold_m[i]).abs() <= 1e-6,
                    "{} hint x{factor}: entry {i}",
                    algo.name()
                );
            }
        }
    }
}

#[test]
fn warm_start_reduces_inverse_order_work() {
    let mut rng = Rng::new(0xD1CE);
    let (g, l) = (400, 24);
    let data = random_signed(&mut rng, g * l, 2.0);
    let mut m1 = data.clone();
    let cold = project_l1inf(&mut m1, g, l, 1.0, Algorithm::InverseOrder);
    let mut m2 = data.clone();
    let warm =
        project_l1inf_with_hint(&mut m2, g, l, 1.0, Algorithm::InverseOrder, Some(cold.theta));
    assert_eq!(warm.stats.theta_hint, Some(cold.theta));
    assert!(
        warm.stats.work < cold.stats.work,
        "warm work {} !< cold work {}",
        warm.stats.work,
        cold.stats.work
    );
}

#[test]
fn theta_cache_feeds_batch_queue() {
    let mut rng = Rng::new(0xAB);
    let (g, l) = (30, 7);
    let cache = ThetaCache::new();
    let pool = BatchProjector::new(3);
    let data = random_signed(&mut rng, g * l, 2.0);
    let mk = |d: Vec<f32>| ProjRequest {
        key: Some("k".into()),
        data: d,
        n_groups: g,
        group_len: l,
        radius: 0.7,
        algo: Algorithm::InverseOrder,
        mode: ProjKind::Exact,
        weights: None,
        depth: l1inf::projection::multilevel::DEFAULT_DEPTH,
    };
    // A queue re-projecting near-identical matrices: first cold, rest warm.
    let queue: Vec<ProjRequest> = (0..6)
        .map(|i| mk(data.iter().map(|v| v * (1.0 + 0.0005 * i as f32)).collect()))
        .collect();
    let first = pool.project_batch(Some(&cache), queue[..1].to_vec());
    assert!(!first[0].warm);
    let rest = pool.project_batch(Some(&cache), queue[1..].to_vec());
    for (i, r) in rest.iter().enumerate() {
        let mut reference = queue[i + 1].data.clone();
        let ri = project_l1inf(&mut reference, g, l, 0.7, Algorithm::InverseOrder);
        for (a, b) in r.data.iter().zip(&reference) {
            assert!((a - b).abs() <= 1e-6, "request {i} output drifted");
        }
        assert!((r.info.theta - ri.theta).abs() <= 1e-9 * ri.theta.max(1.0));
    }
    assert!(cache.stats().hits >= 1, "queue must hit the theta cache");
}

// ── TCP server end to end ───────────────────────────────────────────────

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connecting to test server");
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn roundtrip(&mut self, line: &str) -> json::Json {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response '{resp}': {e}"))
    }
}

#[test]
fn server_projects_over_tcp_with_warm_cache() {
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), threads: 2, ..Default::default() };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());

    let mut client = Client::connect(addr);

    // Ping.
    let pong = client.roundtrip(r#"{"id": 1, "op": "ping"}"#);
    assert_eq!(pong.get("ok"), Some(&json::Json::Bool(true)));
    assert_eq!(pong.get("pong"), Some(&json::Json::Bool(true)));

    // Project a small matrix; verify against the in-process reference.
    let y = vec![1.0f32, -0.5, 0.25, 0.0, 0.9, 0.8, -0.7, 0.1, 1.1, 0.2, 0.3, -0.4];
    let payload: Vec<String> = y.iter().map(|v| format!("{v}")).collect();
    let req = format!(
        r#"{{"id": 2, "op": "project", "key": "w1", "groups": 3, "len": 4, "radius": 1.5, "data": [{}]}}"#,
        payload.join(",")
    );
    let resp = client.roundtrip(&req);
    assert_eq!(resp.get("ok"), Some(&json::Json::Bool(true)), "{resp}");
    let mut reference = y.clone();
    let ri = project_l1inf(&mut reference, 3, 4, 1.5, Algorithm::InverseOrder);
    let theta = resp.get("theta").unwrap().as_f64().unwrap();
    assert!((theta - ri.theta).abs() < 1e-9, "{theta} vs {}", ri.theta);
    let echoed = resp.get("data").unwrap().as_arr().unwrap();
    assert_eq!(echoed.len(), reference.len());
    for (a, b) in echoed.iter().zip(&reference) {
        assert!((a.as_f64().unwrap() - *b as f64).abs() < 1e-6);
    }
    assert_eq!(resp.get("warm"), Some(&json::Json::Bool(false)));

    // Same key again: the θ cache must warm-start without changing results.
    let req2 = req.replace(r#""id": 2"#, r#""id": 3"#);
    let resp2 = client.roundtrip(&req2);
    assert_eq!(resp2.get("warm"), Some(&json::Json::Bool(true)), "{resp2}");
    let theta2 = resp2.get("theta").unwrap().as_f64().unwrap();
    assert!((theta2 - ri.theta).abs() < 1e-9);

    // Malformed request: error response, connection stays usable.
    let err = client.roundtrip(r#"{"id": 4, "op": "project", "groups": 2}"#);
    assert_eq!(err.get("ok"), Some(&json::Json::Bool(false)));
    assert!(err.get("error").unwrap().as_str().unwrap().contains("len"));

    // Stats reflect the served traffic.
    let stats = client.roundtrip(r#"{"id": 5, "op": "stats"}"#);
    assert_eq!(stats.get("served").unwrap().as_usize(), Some(2));
    assert_eq!(stats.get("cache_entries").unwrap().as_usize(), Some(1));
    assert_eq!(stats.get("threads").unwrap().as_usize(), Some(2));

    // Shutdown stops the accept loop and run() returns cleanly.
    let bye = client.roundtrip(r#"{"id": 6, "op": "shutdown"}"#);
    assert_eq!(bye.get("shutting_down"), Some(&json::Json::Bool(true)));
    handle.join().expect("server thread").expect("server run");
}

#[test]
fn server_round_trips_weighted_mode() {
    use l1inf::projection::weighted::project_l1inf_weighted;
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), threads: 2, ..Default::default() };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());
    let mut client = Client::connect(addr);

    let (g, l, c) = (3usize, 4usize, 1.2f64);
    let y = vec![1.0f32, -0.5, 0.25, 0.0, 0.9, 0.8, -0.7, 0.1, 1.1, 0.2, 0.3, -0.4];
    let w = [1.0f32, 2.0, 0.5];
    let payload: Vec<String> = y.iter().map(|v| format!("{v}")).collect();
    let req = format!(
        r#"{{"id": 2, "op": "project", "key": "w1", "mode": "weighted", "groups": {g}, "len": {l}, "radius": {c}, "weights": [1.0, 2.0, 0.5], "data": [{}]}}"#,
        payload.join(",")
    );
    let resp = client.roundtrip(&req);
    assert_eq!(resp.get("ok"), Some(&json::Json::Bool(true)), "{resp}");
    assert_eq!(resp.get("mode").unwrap().as_str(), Some("weighted"));
    assert_eq!(resp.get("warm"), Some(&json::Json::Bool(false)));

    // The echoed matrix matches the in-process weighted operator.
    let mut reference = y.clone();
    let ri = project_l1inf_weighted(&mut reference, g, l, c, &w);
    let lambda = resp.get("theta").unwrap().as_f64().unwrap();
    assert!((lambda - ri.theta).abs() < 1e-9, "{lambda} vs {}", ri.theta);
    let echoed = resp.get("data").unwrap().as_arr().unwrap();
    assert_eq!(echoed.len(), reference.len());
    for (a, b) in echoed.iter().zip(&reference) {
        assert!((a.as_f64().unwrap() - *b as f64).abs() < 1e-6);
    }

    // Same key again: λ warm-starts from the weighted namespace without
    // changing the result.
    let req2 = req.replace(r#""id": 2"#, r#""id": 3"#);
    let resp2 = client.roundtrip(&req2);
    assert_eq!(resp2.get("warm"), Some(&json::Json::Bool(true)), "{resp2}");
    let lambda2 = resp2.get("theta").unwrap().as_f64().unwrap();
    assert!((lambda2 - ri.theta).abs() <= 1e-9 * ri.theta.max(1.0));

    // An exact-mode request under the same key stays cold: λ must not
    // leak into the exact θ namespace.
    let req3 = req
        .replace(r#""id": 2"#, r#""id": 4"#)
        .replace(r#""mode": "weighted", "#, "")
        .replace(r#""weights": [1.0, 2.0, 0.5], "#, "");
    let resp3 = client.roundtrip(&req3);
    assert_eq!(resp3.get("mode").unwrap().as_str(), Some("exact"));
    assert_eq!(resp3.get("warm"), Some(&json::Json::Bool(false)), "{resp3}");

    // Weights on a non-weighted mode are rejected but keep the
    // connection open.
    let bad = req.replace(r#""mode": "weighted", "#, "").replace(r#""id": 2"#, r#""id": 5"#);
    let err = client.roundtrip(&bad);
    assert_eq!(err.get("ok"), Some(&json::Json::Bool(false)));
    assert!(err.get("error").unwrap().as_str().unwrap().contains("weighted"), "{err}");

    let bye = client.roundtrip(r#"{"id": 9, "op": "shutdown"}"#);
    assert_eq!(bye.get("shutting_down"), Some(&json::Json::Bool(true)));
    handle.join().expect("server thread").expect("server run");
}
